//! Gromov–Wasserstein experiments: Fig. 7 (runtimes + relative error),
//! Fig. 8 (sphere↔torus interpolation), Fig. 12 (ablations).

use crate::gw::{fgw_solve, gw_solve, DenseStructure, GwConfig, GwMethod, LowRankStructure};
use crate::integrators::rfd::RfdConfig;
use crate::linalg::Mat;
use crate::pointcloud::random_cloud;
use crate::util::rng::Rng;
use crate::util::timer::timed;
use crate::util::error::Result;

fn uniform(n: usize) -> Vec<f64> {
    vec![1.0 / n as f64; n]
}

/// Random binary node-feature cost for FGW (paper: "random binary labels
/// are generated for each node").
fn binary_feature_cost(n: usize, m: usize, rng: &mut Rng) -> Mat {
    let la: Vec<f64> = (0..n).map(|_| f64::from(rng.below(2) as u32)).collect();
    let lb: Vec<f64> = (0..m).map(|_| f64::from(rng.below(2) as u32)).collect();
    let mut c = Mat::zeros(n, m);
    for i in 0..n {
        for j in 0..m {
            c[(i, j)] = (la[i] - lb[j]).abs();
        }
    }
    c
}

/// Fig. 7: GW-cg / GW-prox / FGW, baseline (dense) vs RFD-injected,
/// runtimes and relative cost error over a size ladder.
pub fn fig7(quick: bool) -> Result<()> {
    println!("=== Fig 7: GW & FGW — dense baseline vs RFD-injected ===");
    let sizes: &[usize] = if quick { &[100, 200, 400] } else { &[250, 500, 1000, 2000] };
    let (eps, lam, m_feat) = (0.3, -0.2, 16);
    let cfg_cg = GwConfig { max_iter: 10, ..Default::default() };
    let cfg_prox =
        GwConfig { method: GwMethod::Proximal, max_iter: 15, ..Default::default() };
    println!(
        "{:>6} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>8}",
        "N", "GWcg(s)", "cgRFD(s)", "prox(s)", "proxRFD", "FGW(s)", "FGWRFD", "relerr"
    );
    for &n in sizes {
        let mut rng = Rng::new(n as u64);
        let pa = random_cloud(n, &mut rng);
        let pb = random_cloud(n, &mut rng);
        let p = uniform(n);
        let rfd_cfg = RfdConfig {
            num_features: m_feat,
            epsilon: eps,
            lambda: lam,
            seed: 1,
            ..Default::default()
        };
        // Dense baselines.
        let (da, _) = timed(|| DenseStructure::diffusion(&pa, eps, lam));
        let db = DenseStructure::diffusion(&pb, eps, lam);
        let (cg_base, t_cg) = timed(|| gw_solve(&da, &db, &p, &p, &cfg_cg));
        let (prox_base, t_prox) = timed(|| gw_solve(&da, &db, &p, &p, &cfg_prox));
        let feat = binary_feature_cost(n, n, &mut rng);
        let (fgw_base, t_fgw) = timed(|| {
            fgw_solve(&da, &db, &p, &p, Some(&feat), &GwConfig { alpha: 0.5, ..cfg_cg.clone() })
        });
        // RFD-injected.
        let la = LowRankStructure::from_rfd(&pa, rfd_cfg.clone());
        let lb = LowRankStructure::from_rfd(&pb, RfdConfig { seed: 2, ..rfd_cfg });
        let (cg_fast, t_cg_r) = timed(|| gw_solve(&la, &lb, &p, &p, &cfg_cg));
        let (_prox_fast, t_prox_r) = timed(|| gw_solve(&la, &lb, &p, &p, &cfg_prox));
        let (_fgw_fast, t_fgw_r) = timed(|| {
            fgw_solve(&la, &lb, &p, &p, Some(&feat), &GwConfig { alpha: 0.5, ..cfg_cg.clone() })
        });
        let rel = (cg_base.cost - cg_fast.cost).abs() / cg_base.cost.abs().max(1e-12);
        println!(
            "{:>6} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>8.3}",
            n, t_cg, t_cg_r, t_prox, t_prox_r, t_fgw, t_fgw_r, rel
        );
        let _ = (prox_base, fgw_base);
    }
    Ok(())
}

/// Fig. 8: GW interpolation between a sphere and a torus — reports the
/// GW cost trajectory of the interpolated structures.
pub fn fig8(quick: bool) -> Result<()> {
    println!("=== Fig 8: GW interpolation sphere ↔ torus ===");
    let n_pts = if quick { 150 } else { 500 };
    let mut rng = Rng::new(3);
    let mut sphere_mesh = crate::mesh::icosphere(3);
    sphere_mesh.normalize_unit_box();
    let mut torus_mesh = crate::mesh::torus(32, 16, 1.0, 0.4);
    torus_mesh.normalize_unit_box();
    let pa = crate::datasets::sample_mesh_points(&sphere_mesh, n_pts, &mut rng);
    let pb = crate::datasets::sample_mesh_points(&torus_mesh, n_pts, &mut rng);
    let (eps, lam) = (0.13, -0.15);
    let cfg = RfdConfig { num_features: 16, epsilon: eps, lambda: lam, seed: 4, ..Default::default() };
    let sa = LowRankStructure::from_rfd(&pa, cfg.clone());
    let sb = LowRankStructure::from_rfd(&pb, RfdConfig { seed: 5, ..cfg });
    let p = uniform(n_pts);
    let gw_cfg = GwConfig { max_iter: 15, ..Default::default() };
    let (res, t) = timed(|| gw_solve(&sa, &sb, &p, &p, &gw_cfg));
    println!("GW(sphere, torus): cost={:.5e}  iters={}  time={:.2}s", res.cost, res.iterations, t);
    // Interpolated barycenter structures at weights w ∈ {0, ¼, ½, ¾, 1}.
    let plans = vec![identity_plan(&p), res.plan.clone()];
    println!("{:>6} {:>14} {:>14}", "w", "selfGW(sphere)", "selfGW(torus)");
    for w in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let bar = crate::gw::gw_barycenter_structure(
            &[&sa, &sb],
            &plans,
            &[1.0 - w, w],
            &p,
        );
        let dbar = DenseStructure::new(bar);
        let to_a = gw_solve(&dbar, &sa, &p, &p, &gw_cfg).cost;
        let to_b = gw_solve(&dbar, &sb, &p, &p, &gw_cfg).cost;
        println!("{:>6} {:>14.5e} {:>14.5e}", w, to_a, to_b);
    }
    Ok(())
}

fn identity_plan(p: &[f64]) -> Mat {
    let mut t = Mat::zeros(p.len(), p.len());
    for (i, &pi) in p.iter().enumerate() {
        t[(i, i)] = pi;
    }
    t
}

/// Fig. 12: GW ablations — runtime vs ε (graph density) and relative
/// error vs ε and λ.
pub fn fig12(quick: bool) -> Result<()> {
    println!("=== Fig 12: GW ablations ===");
    let n = if quick { 150 } else { 600 };
    let cfg_cg = GwConfig { max_iter: 8, ..Default::default() };
    let mut rng = Rng::new(7);
    let pa = random_cloud(n, &mut rng);
    let pb = random_cloud(n, &mut rng);
    let p = uniform(n);
    println!("-- runtime & rel-err vs ε (λ=-0.2, m=16)");
    println!("{:>6} {:>12} {:>12} {:>8}", "eps", "dense(s)", "rfd(s)", "relerr");
    for eps in [0.1, 0.2, 0.3, 0.5, 0.8] {
        let (da, _) = timed(|| DenseStructure::diffusion(&pa, eps, -0.2));
        let db = DenseStructure::diffusion(&pb, eps, -0.2);
        let (base, t_d) = timed(|| gw_solve(&da, &db, &p, &p, &cfg_cg));
        let rc = RfdConfig { num_features: 16, epsilon: eps, lambda: -0.2, seed: 1, ..Default::default() };
        let la = LowRankStructure::from_rfd(&pa, rc.clone());
        let lb = LowRankStructure::from_rfd(&pb, RfdConfig { seed: 2, ..rc });
        let (fast, t_r) = timed(|| gw_solve(&la, &lb, &p, &p, &cfg_cg));
        let rel = (base.cost - fast.cost).abs() / base.cost.abs().max(1e-12);
        println!("{:>6} {:>12.2} {:>12.2} {:>8.3}", eps, t_d, t_r, rel);
    }
    println!("-- rel-err vs λ (ε=0.3, m=16)");
    println!("{:>6} {:>8}", "|λ|", "relerr");
    for lam_abs in [0.05, 0.1, 0.2, 0.4, 0.8] {
        let lam = -lam_abs;
        let da = DenseStructure::diffusion(&pa, 0.3, lam);
        let db = DenseStructure::diffusion(&pb, 0.3, lam);
        let base = gw_solve(&da, &db, &p, &p, &cfg_cg);
        let rc = RfdConfig { num_features: 16, epsilon: 0.3, lambda: lam, seed: 1, ..Default::default() };
        let la = LowRankStructure::from_rfd(&pa, rc.clone());
        let lb = LowRankStructure::from_rfd(&pb, RfdConfig { seed: 2, ..rc });
        let fast = gw_solve(&la, &lb, &p, &p, &cfg_cg);
        let rel = (base.cost - fast.cost).abs() / base.cost.abs().max(1e-12);
        println!("{:>6} {:>8.3}", lam_abs, rel);
    }
    Ok(())
}
