//! Interpolation experiments: Fig. 4 (both rows), Fig. 5, the ablations
//! Figs. 9/10/11, and the mesh-dynamics serving driver (`dynmesh`:
//! per-frame `update_cloud` + SF dirty-subtree refresh vs full
//! re-prepare — the paper's §3.1 deformable-object workload made
//! incremental).

use crate::apps::interpolation::InterpolationTask;
use crate::coordinator::{Engine, UpdateOpts};
use crate::datasets::mesh_zoo;
use crate::integrators::rfd::RfdConfig;
use crate::integrators::sf::SfConfig;
use crate::integrators::trees::TreeKind;
use crate::integrators::{prepare, IntegratorSpec, KernelFn, Scene};
use crate::pointcloud::PointCloud;
use crate::sim::{ClothConfig, ClothSim};
use crate::util::rng::Rng;
use crate::util::timer::timed;
use crate::util::error::Result;

/// Builds the normal-prediction task for a mesh.
fn normal_task(mesh: &crate::mesh::TriMesh, seed: u64) -> InterpolationTask {
    let normals = mesh.vertex_normals();
    let mut rng = Rng::new(seed);
    InterpolationTask::from_vectors(&normals, 0.8, &mut rng)
}

struct Row {
    method: String,
    pre: f64,
    apply: f64,
    cos: f64,
}

fn print_rows(mesh: &str, n: usize, rows: &[Row]) {
    println!("\nmesh={mesh} |V|={n}");
    println!("{:<14} {:>12} {:>12} {:>8}", "method", "preproc(s)", "interp(s)", "cos");
    for r in rows {
        println!("{:<14} {:>12.4} {:>12.4} {:>8.4}", r.method, r.pre, r.apply, r.cos);
    }
}

/// Fig. 4 row 1: SF vs BF vs T-Bart-3/20 vs T-FRT on the mesh ladder.
/// BF and tree baselines are skipped past their practical limits
/// (mirroring the paper's OOM/OOT columns).
pub fn fig4_sf(quick: bool) -> Result<()> {
    let max = if quick { 3_000 } else { 20_000 };
    let bf_limit = if quick { 1_200 } else { 6_000 };
    let tree_limit = if quick { 1_200 } else { 4_000 };
    println!("=== Fig 4 (row 1): shortest-path-kernel integrators ===");
    for entry in mesh_zoo(300, max) {
        let g = entry.mesh.to_graph();
        let n = g.n;
        let scene = Scene::new(
            crate::pointcloud::PointCloud::new(entry.mesh.verts.clone()),
            Some(g.clone()),
        );
        let task = normal_task(&entry.mesh, 7);
        let lambda = 6.0;
        let mut rows = Vec::new();
        // SF
        let (sf, pre) = timed(|| {
            prepare(
                &scene,
                &IntegratorSpec::Sf(SfConfig {
                    kernel: KernelFn::ExpNeg(lambda),
                    unit_size: 0.01,
                    threshold: 512,
                    separator_size: 8,
                    seed: 0,
                }),
            )
        });
        let sf = sf?;
        let ((cos, _), apply) = timed(|| task.evaluate(sf.as_ref()));
        rows.push(Row { method: "SF".into(), pre, apply, cos });
        // Nearest-unmasked copy baseline: one batched multi-source
        // Voronoi sweep through graph::distances — the floor every
        // kernel integrator must beat.
        let (nn_pred, nn_t) = timed(|| task.nearest_unmasked_prediction(&g));
        rows.push(Row {
            method: "NN-copy".into(),
            pre: 0.0,
            apply: nn_t,
            cos: task.score(&nn_pred),
        });
        // BF
        if n <= bf_limit {
            let (bf, pre) =
                timed(|| prepare(&scene, &IntegratorSpec::BfSp(KernelFn::ExpNeg(lambda))));
            let bf = bf?;
            let ((cos, _), apply) = timed(|| task.evaluate(bf.as_ref()));
            rows.push(Row { method: "BF".into(), pre, apply, cos });
        } else {
            rows.push(Row { method: "BF (OOT)".into(), pre: f64::NAN, apply: f64::NAN, cos: f64::NAN });
        }
        // Trees
        for (kind, k, name) in [
            (TreeKind::Bartal, 3usize, "T-Bart-3"),
            (TreeKind::Bartal, 20, "T-Bart-20"),
            (TreeKind::Frt, 3, "T-FRT"),
        ] {
            if n <= tree_limit {
                let (t, pre) = timed(|| {
                    prepare(
                        &scene,
                        &IntegratorSpec::Trees { kind, count: k, lambda, seed: 1 },
                    )
                });
                let t = t?;
                let ((cos, _), apply) = timed(|| task.evaluate(t.as_ref()));
                rows.push(Row { method: name.into(), pre, apply, cos });
            } else {
                rows.push(Row {
                    method: format!("{name} (OOT)"),
                    pre: f64::NAN,
                    apply: f64::NAN,
                    cos: f64::NAN,
                });
            }
        }
        print_rows(&entry.name, n, &rows);
    }
    Ok(())
}

/// Fig. 4 row 2: RFD vs dense/iterative expm-action baselines.
pub fn fig4_rfd(quick: bool) -> Result<()> {
    let max = if quick { 3_000 } else { 20_000 };
    let dense_limit = if quick { 800 } else { 3_000 };
    let iter_limit = if quick { 3_000 } else { 20_000 };
    println!("=== Fig 4 (row 2): diffusion-kernel integrators ===");
    let (eps, lam) = (0.15, 0.5);
    for entry in mesh_zoo(300, max) {
        let n = entry.mesh.num_verts();
        let pc = crate::pointcloud::PointCloud::new(entry.mesh.verts.clone());
        // One scene carries the ε-graph world: RFD integrates the point
        // cloud directly, the expm-action baselines its ε-NN graph.
        let g_eps = pc.epsilon_graph(eps, crate::pointcloud::Norm::LInf, true);
        let scene = Scene::new(pc, Some(g_eps));
        let task = normal_task(&entry.mesh, 8);
        let mut rows = Vec::new();
        // RFD
        let (rfd, pre) = timed(|| {
            prepare(
                &scene,
                &IntegratorSpec::Rfd(RfdConfig {
                    num_features: 128,
                    epsilon: eps,
                    lambda: lam,
                    seed: 0,
                    ..Default::default()
                }),
            )
        });
        let rfd = rfd?;
        let ((cos, _), apply) = timed(|| task.evaluate(rfd.as_ref()));
        rows.push(Row { method: "RFD".into(), pre, apply, cos });
        // Bader (dense) — O(N³)
        if n <= dense_limit {
            let (bd, pre) = timed(|| prepare(&scene, &IntegratorSpec::Bader { lambda: lam }));
            let bd = bd?;
            let ((cos, _), apply) = timed(|| task.evaluate(bd.as_ref()));
            rows.push(Row { method: "Bader".into(), pre, apply, cos });
        } else {
            rows.push(Row { method: "Bader (OOT)".into(), pre: f64::NAN, apply: f64::NAN, cos: f64::NAN });
        }
        // Al-Mohy (matrix-free)
        if n <= iter_limit {
            let (am, pre) =
                timed(|| prepare(&scene, &IntegratorSpec::AlMohy { lambda: lam }));
            let am = am?;
            let ((cos, _), apply) = timed(|| task.evaluate(am.as_ref()));
            rows.push(Row { method: "Al-Mohy".into(), pre, apply, cos });
        }
        // Lanczos
        if n <= iter_limit {
            let (lz, pre) = timed(|| {
                prepare(&scene, &IntegratorSpec::Lanczos { lambda: lam, krylov_dim: 30 })
            });
            let lz = lz?;
            let ((cos, _), apply) = timed(|| task.evaluate(lz.as_ref()));
            rows.push(Row { method: "Lanczos".into(), pre, apply, cos });
        }
        print_rows(&entry.name, n, &rows);
    }
    Ok(())
}

/// Fig. 5: velocity prediction on the deformable flag (cloth-sim
/// substitute for `flag_simple`), 5% mask, four snapshots.
pub fn fig5(quick: bool) -> Result<()> {
    println!("=== Fig 5: velocity prediction on deformable flag ===");
    let cfg = if quick {
        ClothConfig { nx: 24, ny: 18, ..Default::default() }
    } else {
        ClothConfig { nx: 48, ny: 32, ..Default::default() }
    };
    let mut sim = ClothSim::new(cfg);
    println!(
        "{:<10} {:>8} {:>10} {:>10}",
        "snapshot", "|V|", "SF cos", "RFD cos"
    );
    for snap_i in 0..4 {
        let snap = sim.run(300);
        let scene = Scene::from_mesh(&snap.mesh);
        let mut rng = Rng::new(42 + snap_i);
        let task = InterpolationTask::from_vectors(&snap.velocities, 0.05, &mut rng);
        let sf = prepare(
            &scene,
            &IntegratorSpec::Sf(SfConfig {
                kernel: KernelFn::ExpNeg(8.0),
                unit_size: 0.01,
                ..Default::default()
            }),
        )?;
        let (sf_cos, _) = task.evaluate(sf.as_ref());
        let rfd = prepare(
            &scene,
            &IntegratorSpec::Rfd(RfdConfig {
                num_features: 128,
                epsilon: 0.1,
                lambda: 0.5,
                ..Default::default()
            }),
        )?;
        let (rfd_cos, _) = task.evaluate(rfd.as_ref());
        println!(
            "t={:<8.3} {:>8} {:>10.4} {:>10.4}",
            snap.time,
            snap.mesh.num_verts(),
            sf_cos,
            rfd_cos
        );
    }
    Ok(())
}

/// Mesh-dynamics serving: N frames of a deforming icosphere (a traveling
/// surface bump moving ~1% of the vertices per frame) served through the
/// engine's `update_cloud`. Per frame: dirty-set size, separator-tree
/// reuse, incremental-refresh seconds vs a full `prepare` on the updated
/// scene, interpolation quality (vertex normals, 80% mask), and a
/// bitwise check that the refreshed integrator equals the full rebuild.
pub fn dynmesh(quick: bool) -> Result<()> {
    println!("=== Mesh dynamics: update_cloud + SF dirty-subtree refresh ===");
    let mut mesh = crate::mesh::icosphere(if quick { 3 } else { 5 });
    mesh.normalize_unit_box();
    let n = mesh.num_verts();
    let engine = Engine::new(None);
    let id = engine.register_scene(Scene::from_mesh(&mesh), "dynmesh");
    let spec = IntegratorSpec::Sf(SfConfig {
        kernel: KernelFn::ExpNeg(6.0),
        unit_size: 0.01,
        threshold: 512,
        separator_size: 8,
        seed: 0,
    });
    // Warm the cache so frame 1's update has something to refresh.
    let (_, warm) = engine.integrate(id, &spec, &crate::linalg::Mat::zeros(n, 1))?;
    println!(
        "|V|={n}  initial prepare {:.4}s  (threshold=512, |S'|=8)",
        warm.preprocess_seconds
    );
    println!(
        "{:<6} {:>6} {:>14} {:>11} {:>11} {:>8} {:>8} {:>8}",
        "frame", "dirty", "reused/total", "refresh(s)", "full(s)", "speedup", "cos", "bitwise"
    );
    let frames = if quick { 4 } else { 8 };
    for f in 1..=frames {
        // Traveling bump: each frame displaces the ~1% of vertices
        // nearest to a moving center (relative to the base mesh, so the
        // previous frame's bump relaxes back — both regions go dirty).
        let center = (f * 137) % n;
        let amp = 0.03 * (1.0 + 0.5 * (f as f64).sin());
        let verts = crate::mesh::radial_bump(&mesh.verts, center, n / 100, amp);
        let info = engine.update_cloud(id, PointCloud::new(verts.clone()), &UpdateOpts::default())?;
        // Full-prepare baseline on the exact scene the engine now serves.
        let scene_now = engine.cloud(id)?.scene.clone();
        let (full, full_secs) = timed(|| prepare(&scene_now, &spec));
        let full = full?;
        // Interpolation quality on the deformed frame's vertex normals.
        let mut dmesh = mesh.clone();
        dmesh.verts = verts;
        let task = normal_task(&dmesh, 70 + f as u64);
        let (pred, served) = engine.integrate(id, &spec, &task.masked_field)?;
        if !served.cache_hit {
            println!("  (warning: frame {f} was not served by the refreshed artifact)");
        }
        let cos = task.score(&pred);
        let bitwise = pred.data == full.apply(&task.masked_field).data;
        let total = info.reused_nodes + info.rebuilt_nodes;
        println!(
            "{:<6} {:>6} {:>8}/{:<5} {:>11.4} {:>11.4} {:>7.1}x {:>8.4} {:>8}",
            f,
            info.dirty,
            info.reused_nodes,
            total,
            info.refresh_seconds,
            full_secs,
            full_secs / info.refresh_seconds.max(1e-9),
            cos,
            bitwise
        );
    }
    Ok(())
}

/// Fig. 9: RFD ablation over (m, ε, λ) on the vertex-normal task.
pub fn fig9(quick: bool) -> Result<()> {
    println!("=== Fig 9: RFD ablations (vertex normals) ===");
    let mesh = if quick { crate::mesh::icosphere(3) } else { crate::mesh::icosphere(4) };
    let mut m0 = mesh;
    m0.normalize_unit_box();
    let scene = Scene::from_points(crate::pointcloud::PointCloud::new(m0.verts.clone()));
    let task = normal_task(&m0, 3);
    let run = |m: usize, eps: f64, lam: f64| -> (f64, f64, f64) {
        let (rfd, pre) = timed(|| {
            prepare(
                &scene,
                &IntegratorSpec::Rfd(RfdConfig {
                    num_features: m,
                    epsilon: eps,
                    lambda: lam,
                    seed: 0,
                    ..Default::default()
                }),
            )
            .expect("fig9 rfd prepare")
        });
        let ((cos, _), apply) = timed(|| task.evaluate(rfd.as_ref()));
        (pre, apply, cos)
    };
    println!("-- sweep m (ε=0.15, λ=0.5)");
    println!("{:>6} {:>12} {:>12} {:>8}", "m", "preproc(s)", "interp(s)", "cos");
    for m in [8, 32, 64, 128, 256] {
        let (p, a, c) = run(m, 0.15, 0.5);
        println!("{m:>6} {p:>12.4} {a:>12.4} {c:>8.4}");
    }
    println!("-- sweep ε (m=128, λ=0.5)");
    for eps in [0.05, 0.1, 0.15, 0.25, 0.4] {
        let (_, _, c) = run(128, eps, 0.5);
        println!("eps={eps:<6} cos={c:.4}");
    }
    println!("-- sweep λ (m=128, ε=0.15)");
    for lam in [0.05, 0.1, 0.3, 0.5, 1.0] {
        let (_, _, c) = run(128, 0.15, lam);
        println!("lambda={lam:<6} cos={c:.4}");
    }
    Ok(())
}

/// Fig. 10: SF unit-size ablation.
pub fn fig10(quick: bool) -> Result<()> {
    println!("=== Fig 10: SF unit-size ablation ===");
    let mesh = if quick { crate::mesh::icosphere(3) } else { crate::mesh::icosphere(4) };
    let mut m0 = mesh;
    m0.normalize_unit_box();
    let scene = Scene::from_mesh(&m0);
    let n = scene.len();
    let task = normal_task(&m0, 4);
    println!("{:>10} {:>12} {:>12} {:>8}", "unit", "preproc(s)", "interp(s)", "cos");
    for unit in [0.002, 0.01, 0.05, 0.1, 0.3] {
        let (sf, pre) = timed(|| {
            prepare(
                &scene,
                &IntegratorSpec::Sf(SfConfig {
                    kernel: KernelFn::ExpNeg(6.0),
                    unit_size: unit,
                    threshold: n / 2,
                    ..Default::default()
                }),
            )
        });
        let sf = sf?;
        let ((cos, _), apply) = timed(|| task.evaluate(sf.as_ref()));
        println!("{unit:>10} {pre:>12.4} {apply:>12.4} {cos:>8.4}");
    }
    Ok(())
}

/// Fig. 11: SF threshold ablation (accuracy vs interp-time trade-off).
pub fn fig11(quick: bool) -> Result<()> {
    println!("=== Fig 11: SF threshold ablation ===");
    let mesh = if quick { crate::mesh::icosphere(3) } else { crate::mesh::icosphere(4) };
    let mut m0 = mesh;
    m0.normalize_unit_box();
    let scene = Scene::from_mesh(&m0);
    let n = scene.len();
    let task = normal_task(&m0, 5);
    println!("{:>10} {:>12} {:>12} {:>8}", "threshold", "preproc(s)", "interp(s)", "cos");
    for frac in [0.05, 0.125, 0.25, 0.5, 1.0] {
        let threshold = ((n as f64) * frac) as usize;
        let (sf, pre) = timed(|| {
            prepare(
                &scene,
                &IntegratorSpec::Sf(SfConfig {
                    kernel: KernelFn::ExpNeg(6.0),
                    unit_size: 0.01,
                    threshold,
                    ..Default::default()
                }),
            )
        });
        let sf = sf?;
        let ((cos, _), apply) = timed(|| task.evaluate(sf.as_ref()));
        println!("{threshold:>10} {pre:>12.4} {apply:>12.4} {cos:>8.4}");
    }
    Ok(())
}
