//! Classification experiments: Table 4 (point clouds) and Table 8
//! (graphs).

use crate::classify::graph_kernels::{
    fb_features, rfd_graph_features, rw_features, vh_features, wl_sp_features,
};
use crate::classify::{bf_spectral_features, forest_accuracy, rfd_spectral_features, RandomForestConfig};
use crate::datasets::{cubes_dataset, graph_dataset, shape_dataset, ShapeDataset};
use crate::integrators::rfd::RfdConfig;
use crate::linalg::Mat;
use crate::util::error::Result;

fn split_80_20(n: usize) -> (Vec<usize>, Vec<usize>) {
    let cut = (n * 4) / 5;
    ((0..cut).collect(), (cut..n).collect())
}

fn eval_features(
    ds: &ShapeDataset,
    features: impl Fn(&crate::pointcloud::PointCloud) -> Vec<f64> + Sync,
) -> f64 {
    let feats: Vec<Vec<f64>> =
        crate::util::par::par_map(ds.clouds.len(), |i| features(&ds.clouds[i]));
    let k = feats[0].len();
    let (train_idx, test_idx) = split_80_20(ds.clouds.len());
    let pack = |idx: &[usize]| {
        let mut m = Mat::zeros(idx.len(), k);
        let mut y = Vec::with_capacity(idx.len());
        for (r, &i) in idx.iter().enumerate() {
            m.row_mut(r).copy_from_slice(&feats[i]);
            y.push(ds.labels[i]);
        }
        (m, y)
    };
    let (train_x, train_y) = pack(&train_idx);
    let (test_x, test_y) = pack(&test_idx);
    forest_accuracy(
        &train_x,
        &train_y,
        &test_x,
        &test_y,
        ds.num_classes,
        &RandomForestConfig::default(),
    )
}

/// Table 4: point-cloud classification — brute-force dense spectra vs RFD
/// low-rank spectra (k smallest kernel eigenvalues → random forest).
pub fn table4(quick: bool) -> Result<()> {
    println!("=== Table 4: point-cloud classification ===");
    let (per_class, pts) = if quick { (8, 96) } else { (24, 256) };
    let modelnet = shape_dataset(per_class, pts, 0.01, 1);
    let cubes = cubes_dataset(if quick { 8 } else { 23 }, per_class, pts, 0.01, 2);
    println!(
        "{:<12} {:>8} {:>9} {:>10} {:>8}",
        "dataset", "#clouds", "#classes", "baseline", "RFD"
    );
    for (name, ds, k) in [("ModelNet10~", &modelnet, 32usize), ("Cubes~", &cubes, 16)] {
        let (eps, lam) = (0.1, -0.1);
        let cfg = RfdConfig { num_features: 32, epsilon: eps, lambda: lam, ..Default::default() };
        let acc_rfd = eval_features(ds, |pc| rfd_spectral_features(pc, &cfg, k));
        let acc_bf = eval_features(ds, |pc| bf_spectral_features(pc, eps, lam, k));
        println!(
            "{:<12} {:>8} {:>9} {:>10.3} {:>8.3}",
            name,
            ds.clouds.len(),
            ds.num_classes,
            acc_bf,
            acc_rfd
        );
    }
    Ok(())
}

/// Table 8: graph classification — RFD kernel vs VH/RW/WL-SP/FB.
pub fn table8(quick: bool) -> Result<()> {
    println!("=== Table 8: graph classification ===");
    let per_class = if quick { 15 } else { 50 };
    let (graphs, labels, ncls) = graph_dataset(per_class, 3);
    let n = graphs.len();
    let (train_idx, test_idx) = split_80_20(n);
    let rfd_cfg = RfdConfig { num_features: 16, epsilon: 0.5, lambda: -0.3, ..Default::default() };
    let methods: Vec<(&str, Box<dyn Fn(usize) -> Vec<f64> + Sync>)> = vec![
        ("VH", Box::new(|i: usize| vh_features(&graphs[i], 4))),
        ("RW", Box::new(|i: usize| rw_features(&graphs[i], 5))),
        ("WL-SP", Box::new(|i: usize| wl_sp_features(&graphs[i], 8, 4))),
        ("FB", Box::new(|i: usize| fb_features(&graphs[i], 8))),
        ("RFD(ours)", Box::new(|i: usize| rfd_graph_features(&graphs[i], &rfd_cfg, 8))),
    ];
    println!("{:<10} {:>8}", "method", "accuracy");
    for (name, feat) in &methods {
        let feats: Vec<Vec<f64>> = crate::util::par::par_map(n, |i| feat(i));
        let k = feats[0].len();
        let pack = |idx: &[usize]| {
            let mut m = Mat::zeros(idx.len(), k);
            let mut y = Vec::new();
            for (r, &i) in idx.iter().enumerate() {
                m.row_mut(r).copy_from_slice(&feats[i]);
                y.push(labels[i]);
            }
            (m, y)
        };
        let (tx, ty) = pack(&train_idx);
        let (vx, vy) = pack(&test_idx);
        let acc = forest_accuracy(&tx, &ty, &vx, &vy, ncls, &RandomForestConfig::default());
        println!("{:<10} {:>8.3}", name, acc);
    }
    Ok(())
}
