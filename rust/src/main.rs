//! `repro` — the gfi CLI: serve the GFI coordinator, regenerate the
//! paper's tables/figures, or run a one-shot integration.
//!
//! ```text
//! repro serve [--addr 127.0.0.1:7878] [--artifacts artifacts]
//! repro reproduce <experiment-id|all> [--quick]
//! repro list
//! repro selfcheck [--artifacts artifacts]
//! ```
//!
//! (Hand-rolled arg parsing: the offline build has no clap.)

use gfi::util::error::{bail, Result};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt<'a>(args: &'a [String], name: &str, default: &'a str) -> &'a str {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
        .unwrap_or(default)
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("serve") => serve(args),
        Some("reproduce") => {
            let id = args
                .get(1)
                .filter(|a| !a.starts_with("--"))
                .map(String::as_str)
                .unwrap_or("all");
            gfi::repro::run(id, flag(args, "--quick"))
        }
        Some("list") => {
            gfi::repro::list();
            Ok(())
        }
        Some("selfcheck") => selfcheck(args),
        Some(other) => bail!("unknown command '{other}' (serve | reproduce | list | selfcheck)"),
        None => {
            println!(
                "gfi {} — Efficient Graph Field Integrators Meet Point Clouds",
                gfi::version()
            );
            println!("usage: repro <serve|reproduce|list|selfcheck> [options]");
            gfi::repro::list();
            Ok(())
        }
    }
}

fn serve(args: &[String]) -> Result<()> {
    let addr = opt(args, "--addr", "127.0.0.1:7878");
    let artifacts = opt(args, "--artifacts", "artifacts");
    let dir = std::path::Path::new(artifacts);
    let engine = Arc::new(gfi::coordinator::Engine::new(
        dir.join("manifest.json").exists().then_some(dir),
    ));
    println!(
        "gfi coordinator: pjrt={} (artifacts: {artifacts})",
        engine.has_pjrt()
    );
    gfi::coordinator::server::serve(engine, addr, |a| {
        println!("listening on {a} (JSON lines; send {{\"op\":\"shutdown\"}} to stop)");
    })
}

/// Smoke check of the whole stack: SF + RFD on a small sphere, PJRT
/// round-trip when artifacts exist — all through the unified
/// spec → prepare → apply lifecycle.
fn selfcheck(args: &[String]) -> Result<()> {
    use gfi::integrators::{prepare, FieldIntegrator, IntegratorSpec, KernelFn, Scene};
    let artifacts = opt(args, "--artifacts", "artifacts");
    let mut mesh = gfi::mesh::icosphere(2);
    mesh.normalize_unit_box();
    let scene = Scene::from_mesh(&mesh);
    let n = scene.len();
    println!("mesh: icosphere(2), |V|={n}");
    let mut rng = gfi::util::rng::Rng::new(1);
    let field =
        gfi::linalg::Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.gaussian()).collect());
    let bf: Box<dyn FieldIntegrator> =
        prepare(&scene, &IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0)))?;
    let exact = bf.apply(&field);
    let sf = prepare(
        &scene,
        &IntegratorSpec::Sf(gfi::integrators::sf::SfConfig {
            kernel: KernelFn::ExpNeg(2.0),
            ..Default::default()
        }),
    )?;
    let e_sf = gfi::util::stats::rel_err(&sf.apply(&field).data, &exact.data);
    println!("SF vs BF rel err: {e_sf:.4}");
    let cfg = gfi::integrators::rfd::RfdConfig { num_features: 16, ..Default::default() };
    let rfd = prepare(&scene, &IntegratorSpec::Rfd(cfg.clone()))?;
    let rust_out = rfd.apply(&field);
    println!("RFD pure-rust: ok ({} outputs)", rust_out.data.len());
    let dir = std::path::Path::new(artifacts);
    if dir.join("manifest.json").exists() {
        let rt = gfi::runtime::PjrtRuntime::new(dir)?;
        let (omegas, qscale) = gfi::integrators::rfd::sample_features(&cfg);
        let pjrt_out =
            rt.rfd_apply(&scene.points.points, &omegas, &qscale, &field, cfg.lambda)?;
        let e = gfi::util::stats::rel_err(&pjrt_out.data, &rust_out.data);
        println!("RFD PJRT vs rust rel err: {e:.2e}");
        if e > 1e-3 {
            bail!("PJRT/rust mismatch");
        }
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT check)");
    }
    println!("selfcheck OK");
    Ok(())
}
