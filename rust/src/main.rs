//! `repro` — the gfi CLI: serve the GFI coordinator, regenerate the
//! paper's tables/figures, or run a one-shot integration.
//!
//! ```text
//! repro serve [--addr 127.0.0.1:7878] [--artifacts artifacts]
//!             [--store] [--store-disk-mb MB] [--store-fsync]
//!             [--shards 8] [--max-resident-mb MB] [--max-clouds N]
//!             [--max-conns 64] [--read-timeout-ms MS]
//!             [--write-timeout-ms MS] [--deadline-ms MS]
//!             [--faults PLAN] [--threaded]
//!             [--batch-window-us US] [--workers N]
//! repro reproduce <experiment-id|all> [--quick]
//! repro list
//! repro selfcheck [--artifacts artifacts]
//! repro analyze [--root DIR] [--list-rules]
//! ```
//!
//! `--max-resident-mb` bounds the prepared-integrator cache (LRU
//! eviction past the budget), `--max-clouds` bounds registered scenes,
//! `--shards` sets cache lock sharding, and `--max-conns` caps
//! concurrent server connections. Unset = unbounded (the pre-cache
//! behavior). `--read-timeout-ms`/`--write-timeout-ms` override the
//! slow-client socket timeouts (0 disables), `--deadline-ms` sets a
//! default per-request deadline budget, and `--faults` arms the
//! deterministic fault injector with a chaos plan (same syntax as the
//! `GFI_FAULTS` env var — see docs/ARCHITECTURE.md, "Failure model").
//! `--store` enables the persistent structure store (spill-to-disk
//! cache under `<artifacts>/structures/` — warm restarts serve at
//! kernel-stage-only cost); `--store-disk-mb` bounds its disk usage
//! and `--store-fsync` makes every spill fsync before rename.
//!
//! The default front-end (on Unix) is the event-driven server: binary
//! frames with pipelining, line-JSON compat auto-detected, and
//! cross-connection micro-batching over `--batch-window-us`
//! microseconds (0 disables) on `--workers` threads (0 = CPU cores).
//! `--threaded` selects the legacy blocking thread-per-connection
//! JSON-lines server instead.
//! See docs/ARCHITECTURE.md and docs/PROTOCOL.md.
//!
//! (Hand-rolled arg parsing: the offline build has no clap.)

use gfi::util::error::{bail, Result};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt<'a>(args: &'a [String], name: &str, default: &'a str) -> &'a str {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
        .unwrap_or(default)
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("serve") => serve(args),
        Some("reproduce") => {
            let id = args
                .get(1)
                .filter(|a| !a.starts_with("--"))
                .map(String::as_str)
                .unwrap_or("all");
            gfi::repro::run(id, flag(args, "--quick"))
        }
        Some("list") => {
            gfi::repro::list();
            Ok(())
        }
        Some("selfcheck") => selfcheck(args),
        // The in-tree invariant analyzer (docs/ARCHITECTURE.md, "Static
        // analysis"). Exits directly: its exit code (0 clean, 1 findings,
        // 2 errors) is the CI contract and must not be flattened into
        // the generic error path.
        Some("analyze") => std::process::exit(gfi::analysis::cli_main(&args[1..])),
        Some(other) => {
            bail!("unknown command '{other}' (serve | reproduce | list | selfcheck | analyze)")
        }
        None => {
            println!(
                "gfi {} — Efficient Graph Field Integrators Meet Point Clouds",
                gfi::version()
            );
            println!("usage: repro <serve|reproduce|list|selfcheck> [options]");
            gfi::repro::list();
            Ok(())
        }
    }
}

fn serve(args: &[String]) -> Result<()> {
    let addr = opt(args, "--addr", "127.0.0.1:7878");
    let artifacts = opt(args, "--artifacts", "artifacts");
    let parse_num = |name: &str| -> Result<Option<u64>> {
        let raw = opt(args, name, "");
        if raw.is_empty() {
            Ok(None)
        } else {
            raw.parse::<u64>()
                .map(Some)
                .map_err(|_| gfi::anyhow!("{name} expects a non-negative integer, got '{raw}'"))
        }
    };
    let mut cfg = gfi::coordinator::EngineConfig::default();
    let dir = std::path::Path::new(artifacts);
    // The artifacts dir now serves two consumers (PJRT manifests at its
    // top level, the structure store under `structures/`), so it is
    // passed through whenever either needs it; the engine validates it
    // once at build time and reports problems as typed config warnings.
    if flag(args, "--store") || dir.join("manifest.json").exists() {
        cfg = cfg.artifacts(dir);
    }
    if flag(args, "--store") {
        cfg = cfg.store(true);
    }
    if let Some(mb) = parse_num("--store-disk-mb")? {
        cfg = cfg.store_disk_bytes(mb.saturating_mul(1 << 20));
    }
    if flag(args, "--store-fsync") {
        cfg = cfg.store_fsync(true);
    }
    if let Some(n) = parse_num("--shards")? {
        cfg = cfg.shards(n as usize);
    }
    if let Some(mb) = parse_num("--max-resident-mb")? {
        cfg = cfg.max_resident_bytes(mb.saturating_mul(1 << 20));
    }
    if let Some(n) = parse_num("--max-clouds")? {
        cfg = cfg.max_clouds(n as usize);
    }
    let faults = opt(args, "--faults", "");
    if !faults.is_empty() {
        let plan = gfi::coordinator::faults::FaultPlan::parse(faults)
            .map_err(|e| gfi::anyhow!("--faults: {e}"))?;
        cfg = cfg.fault_plan(plan);
    }
    let mut server_cfg = gfi::coordinator::server::ServerConfig::default();
    if let Some(n) = parse_num("--max-conns")? {
        server_cfg.max_connections = n as usize;
    }
    if let Some(ms) = parse_num("--read-timeout-ms")? {
        server_cfg.read_timeout_ms = ms;
    }
    if let Some(ms) = parse_num("--write-timeout-ms")? {
        server_cfg.write_timeout_ms = ms;
    }
    if let Some(ms) = parse_num("--deadline-ms")? {
        server_cfg.request_deadline_ms = ms;
    }
    if let Some(us) = parse_num("--batch-window-us")? {
        server_cfg.batch_window_us = us;
    }
    if let Some(n) = parse_num("--workers")? {
        server_cfg.workers = n as usize;
    }
    let threaded = flag(args, "--threaded") || cfg!(not(unix));
    let engine = Arc::new(cfg.build());
    for w in engine.config_warnings() {
        eprintln!("warning [{}]: {}", w.component, w.detail);
    }
    let ecfg = engine.config();
    println!(
        "gfi coordinator: mode={}, pjrt={}, store={} (artifacts: {artifacts}), shards={}, \
         max_resident_bytes={}, max_clouds={}, max_conns={}, \
         read_timeout_ms={}, deadline_ms={}, batch_window_us={}, faults_armed={}",
        if threaded { "threaded" } else { "evented" },
        engine.has_pjrt(),
        engine.store_stats().is_some(),
        ecfg.shards,
        if ecfg.max_resident_bytes == u64::MAX {
            "unbounded".to_string()
        } else {
            ecfg.max_resident_bytes.to_string()
        },
        if ecfg.max_clouds == usize::MAX {
            "unbounded".to_string()
        } else {
            ecfg.max_clouds.to_string()
        },
        server_cfg.max_connections,
        server_cfg.read_timeout_ms,
        server_cfg.request_deadline_ms,
        server_cfg.batch_window_us,
        engine.faults().armed(),
    );
    if threaded {
        return gfi::coordinator::server::serve_with(engine, addr, server_cfg, |a| {
            println!("listening on {a} (JSON lines; send {{\"op\":\"shutdown\"}} to stop)");
        });
    }
    serve_evented(engine, addr, server_cfg)
}

#[cfg(unix)]
fn serve_evented(
    engine: Arc<gfi::coordinator::Engine>,
    addr: &str,
    server_cfg: gfi::coordinator::server::ServerConfig,
) -> Result<()> {
    gfi::coordinator::evented::serve_evented_with(engine, addr, server_cfg, |a| {
        println!(
            "listening on {a} (binary frames + JSON-lines compat; \
             send {{\"op\":\"shutdown\"}} to stop)"
        );
    })
}

#[cfg(not(unix))]
fn serve_evented(
    engine: Arc<gfi::coordinator::Engine>,
    addr: &str,
    server_cfg: gfi::coordinator::server::ServerConfig,
) -> Result<()> {
    // Unreachable: `threaded` is forced on non-Unix above.
    gfi::coordinator::server::serve_with(engine, addr, server_cfg, |a| {
        println!("listening on {a} (JSON lines; send {{\"op\":\"shutdown\"}} to stop)");
    })
}

/// Smoke check of the whole stack: SF + RFD on a small sphere, PJRT
/// round-trip when artifacts exist — all through the unified
/// spec → prepare → apply lifecycle.
fn selfcheck(args: &[String]) -> Result<()> {
    use gfi::integrators::{prepare, FieldIntegrator, IntegratorSpec, KernelFn, Scene};
    let artifacts = opt(args, "--artifacts", "artifacts");
    let mut mesh = gfi::mesh::icosphere(2);
    mesh.normalize_unit_box();
    let scene = Scene::from_mesh(&mesh);
    let n = scene.len();
    println!("mesh: icosphere(2), |V|={n}");
    let mut rng = gfi::util::rng::Rng::new(1);
    let field =
        gfi::linalg::Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.gaussian()).collect());
    let bf: Box<dyn FieldIntegrator> =
        prepare(&scene, &IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0)))?;
    let exact = bf.apply(&field);
    let sf = prepare(
        &scene,
        &IntegratorSpec::Sf(gfi::integrators::sf::SfConfig {
            kernel: KernelFn::ExpNeg(2.0),
            ..Default::default()
        }),
    )?;
    let e_sf = gfi::util::stats::rel_err(&sf.apply(&field).data, &exact.data);
    println!("SF vs BF rel err: {e_sf:.4}");
    let cfg = gfi::integrators::rfd::RfdConfig { num_features: 16, ..Default::default() };
    let rfd = prepare(&scene, &IntegratorSpec::Rfd(cfg.clone()))?;
    let rust_out = rfd.apply(&field);
    println!("RFD pure-rust: ok ({} outputs)", rust_out.data.len());
    let dir = std::path::Path::new(artifacts);
    if dir.join("manifest.json").exists() {
        let rt = gfi::runtime::PjrtRuntime::new(dir)?;
        let (omegas, qscale) = gfi::integrators::rfd::sample_features(&cfg);
        let pjrt_out =
            rt.rfd_apply(&scene.points.points, &omegas, &qscale, &field, cfg.lambda)?;
        let e = gfi::util::stats::rel_err(&pjrt_out.data, &rust_out.data);
        println!("RFD PJRT vs rust rel err: {e:.2e}");
        if e > 1e-3 {
            bail!("PJRT/rust mismatch");
        }
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT check)");
    }
    println!("selfcheck OK");
    Ok(())
}
