//! Point-cloud and graph classification with the RFD kernel
//! (paper §3.3 Table 4 + Appendix F Table 8).
//!
//! Pipeline: per shape/graph, compute the `k` smallest eigenvalues of the
//! diffusion kernel matrix — via RFD's low-rank factorization (`O(N)`)
//! or the dense brute force (`O(N³)`) — and feed the spectra to a random
//! forest.

pub mod forest;
pub mod graph_kernels;

pub use forest::{RandomForest, RandomForestConfig};

use crate::integrators::rfd::{RfDiffusion, RfdConfig};
use crate::linalg::{eigh_tridiagonal, expm_pade, Mat};
use crate::pointcloud::{Norm, PointCloud};

/// RFD spectral features: `k` smallest eigenvalues of `exp(Λ(Ŵ − δI))`.
pub fn rfd_spectral_features(points: &PointCloud, cfg: &RfdConfig, k: usize) -> Vec<f64> {
    let rfd = RfDiffusion::try_new(points, cfg.clone())
        .expect("rfd_spectral_features: RFD preparation failed");
    rfd.kernel_eigenvalues(k, points.len())
}

/// Brute-force spectral features: dense ε-graph adjacency, full symmetric
/// eigendecomposition, exponentiate eigenvalues, take the `k` smallest
/// (paper: "directly conducting the eigendecomposition of its adjacency
/// matrix and exponentiating eigenvalues").
pub fn bf_spectral_features(
    points: &PointCloud,
    epsilon: f64,
    lambda: f64,
    k: usize,
) -> Vec<f64> {
    let w = points.dense_adjacency(epsilon, Norm::LInf, true);
    let mut eigs = eigh_tridiagonal(&w);
    for e in eigs.iter_mut() {
        *e = (lambda * *e).exp();
    }
    eigs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    eigs.truncate(k);
    // Pad if the cloud is smaller than k.
    while eigs.len() < k {
        eigs.push(0.0);
    }
    eigs
}

/// Dense diffusion-kernel spectral features via expm (exact oracle for
/// tests).
pub fn dense_kernel_eigs(points: &PointCloud, epsilon: f64, lambda: f64, k: usize) -> Vec<f64> {
    let w = points.dense_adjacency(epsilon, Norm::LInf, true);
    let kmat = expm_pade(&w.scale(lambda));
    let mut eigs = crate::linalg::eigh_jacobi(&kmat).values;
    eigs.truncate(k);
    eigs
}

/// Train/test accuracy of a random forest over feature vectors.
pub fn forest_accuracy(
    train_x: &Mat,
    train_y: &[usize],
    test_x: &Mat,
    test_y: &[usize],
    num_classes: usize,
    cfg: &RandomForestConfig,
) -> f64 {
    let forest = RandomForest::fit(train_x, train_y, num_classes, cfg);
    let mut correct = 0usize;
    for i in 0..test_x.rows {
        if forest.predict(test_x.row(i)) == test_y[i] {
            correct += 1;
        }
    }
    correct as f64 / test_x.rows.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pointcloud::random_cloud;
    use crate::util::rng::Rng;

    #[test]
    fn bf_features_match_dense_kernel_eigs() {
        // exp(λ·eig(W)) == eig(exp(λW)) for symmetric W.
        let mut rng = Rng::new(1);
        let pc = random_cloud(40, &mut rng);
        let a = bf_spectral_features(&pc, 0.3, -0.2, 8);
        let b = dense_kernel_eigs(&pc, 0.3, -0.2, 8);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn rfd_features_finite_and_sorted() {
        let mut rng = Rng::new(2);
        let pc = random_cloud(60, &mut rng);
        let cfg = RfdConfig { num_features: 16, epsilon: 0.2, lambda: -0.1, ..Default::default() };
        let f = rfd_spectral_features(&pc, &cfg, 10);
        assert_eq!(f.len(), 10);
        assert!(f.iter().all(|x| x.is_finite()));
        for w in f.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn spectra_distinguish_dense_from_sparse_clouds() {
        // A tight cluster (everything within ε) vs a spread cloud: the
        // kernel spectra must differ notably — the classification signal.
        let mut rng = Rng::new(3);
        let spread = random_cloud(50, &mut rng);
        let mut tight = random_cloud(50, &mut rng);
        for p in tight.points.iter_mut() {
            for k in 0..3 {
                p[k] *= 0.05;
            }
        }
        let cfg = RfdConfig { num_features: 32, epsilon: 0.2, lambda: -0.1, ..Default::default() };
        let fs = rfd_spectral_features(&spread, &cfg, 5);
        let ft = rfd_spectral_features(&tight, &cfg, 5);
        let diff: f64 = fs.iter().zip(&ft).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-3, "spectra identical: {diff}");
    }
}
