//! Random-forest classifier (the downstream model of paper §3.3): CART
//! trees with Gini impurity, bootstrap sampling, and √d feature
//! subsampling at each split.

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Forest hyper-parameters.
#[derive(Clone, Debug)]
pub struct RandomForestConfig {
    pub num_trees: usize,
    pub max_depth: usize,
    pub min_leaf: usize,
    pub seed: u64,
}

impl Default for RandomForestConfig {
    fn default() -> Self {
        RandomForestConfig { num_trees: 64, max_depth: 10, min_leaf: 2, seed: 0 }
    }
}

enum Node {
    Leaf {
        /// Majority class.
        class: usize,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: Box<Node>,
        right: Box<Node>,
    },
}

/// A trained random forest.
pub struct RandomForest {
    trees: Vec<Node>,
    num_classes: usize,
}

impl RandomForest {
    pub fn fit(x: &Mat, y: &[usize], num_classes: usize, cfg: &RandomForestConfig) -> Self {
        assert_eq!(x.rows, y.len());
        assert!(num_classes >= 2);
        let mut rng = Rng::new(cfg.seed);
        let trees = (0..cfg.num_trees)
            .map(|_| {
                // Bootstrap sample.
                let idx: Vec<usize> = (0..x.rows).map(|_| rng.below(x.rows)).collect();
                build_tree(x, y, &idx, num_classes, cfg, &mut rng, 0)
            })
            .collect();
        RandomForest { trees, num_classes }
    }

    /// Majority vote over trees.
    pub fn predict(&self, features: &[f64]) -> usize {
        let mut votes = vec![0usize; self.num_classes];
        for t in &self.trees {
            votes[classify(t, features)] += 1;
        }
        votes
            .iter()
            .enumerate()
            .max_by_key(|&(_, v)| *v)
            .map(|(c, _)| c)
            .unwrap_or(0)
    }
}

fn classify(node: &Node, f: &[f64]) -> usize {
    match node {
        Node::Leaf { class } => *class,
        Node::Split { feature, threshold, left, right } => {
            if f[*feature] <= *threshold {
                classify(left, f)
            } else {
                classify(right, f)
            }
        }
    }
}

fn majority(y: &[usize], idx: &[usize], num_classes: usize) -> usize {
    let mut counts = vec![0usize; num_classes];
    for &i in idx {
        counts[y[i]] += 1;
    }
    counts
        .iter()
        .enumerate()
        .max_by_key(|&(_, c)| *c)
        .map(|(cl, _)| cl)
        .unwrap_or(0)
}

fn gini(counts: &[usize], total: usize) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - counts.iter().map(|&c| (c as f64 / t).powi(2)).sum::<f64>()
}

#[allow(clippy::too_many_arguments)]
fn build_tree(
    x: &Mat,
    y: &[usize],
    idx: &[usize],
    num_classes: usize,
    cfg: &RandomForestConfig,
    rng: &mut Rng,
    depth: usize,
) -> Node {
    // Stop conditions.
    let first = y[idx[0]];
    let pure = idx.iter().all(|&i| y[i] == first);
    if pure || depth >= cfg.max_depth || idx.len() <= cfg.min_leaf {
        return Node::Leaf { class: majority(y, idx, num_classes) };
    }
    let d = x.cols;
    let n_try = ((d as f64).sqrt().ceil() as usize).clamp(1, d);
    let feats = rng.sample_indices(d, n_try);
    let mut best: Option<(f64, usize, f64)> = None; // (gini gain proxy, feature, threshold)
    let parent_gini = {
        let mut counts = vec![0usize; num_classes];
        for &i in idx {
            counts[y[i]] += 1;
        }
        gini(&counts, idx.len())
    };
    for &f in &feats {
        // Sort indices by feature value; evaluate midpoints.
        let mut vals: Vec<(f64, usize)> = idx.iter().map(|&i| (x[(i, f)], y[i])).collect();
        vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let total = vals.len();
        let mut left_counts = vec![0usize; num_classes];
        let mut right_counts = vec![0usize; num_classes];
        for &(_, cls) in &vals {
            right_counts[cls] += 1;
        }
        for s in 0..total - 1 {
            let cls = vals[s].1;
            left_counts[cls] += 1;
            right_counts[cls] -= 1;
            if vals[s].0 == vals[s + 1].0 {
                continue; // no valid threshold between equal values
            }
            let nl = s + 1;
            let nr = total - nl;
            let w_gini = (nl as f64 * gini(&left_counts, nl)
                + nr as f64 * gini(&right_counts, nr))
                / total as f64;
            let gain = parent_gini - w_gini;
            let thr = 0.5 * (vals[s].0 + vals[s + 1].0);
            if best.map(|(bg, _, _)| gain > bg).unwrap_or(gain > 1e-12) {
                best = Some((gain, f, thr));
            }
        }
    }
    match best {
        None => Node::Leaf { class: majority(y, idx, num_classes) },
        Some((_, feature, threshold)) => {
            let left_idx: Vec<usize> =
                idx.iter().copied().filter(|&i| x[(i, feature)] <= threshold).collect();
            let right_idx: Vec<usize> =
                idx.iter().copied().filter(|&i| x[(i, feature)] > threshold).collect();
            if left_idx.is_empty() || right_idx.is_empty() {
                return Node::Leaf { class: majority(y, idx, num_classes) };
            }
            Node::Split {
                feature,
                threshold,
                left: Box::new(build_tree(x, y, &left_idx, num_classes, cfg, rng, depth + 1)),
                right: Box::new(build_tree(x, y, &right_idx, num_classes, cfg, rng, depth + 1)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob_data(n_per: usize, seed: u64) -> (Mat, Vec<usize>) {
        // Three Gaussian blobs in 4-D.
        let mut rng = Rng::new(seed);
        let centers = [
            [0.0, 0.0, 0.0, 0.0],
            [3.0, 3.0, 0.0, -1.0],
            [-3.0, 2.0, 4.0, 1.0],
        ];
        let mut data = Vec::new();
        let mut labels = Vec::new();
        for (c, center) in centers.iter().enumerate() {
            for _ in 0..n_per {
                for k in 0..4 {
                    data.push(center[k] + 0.5 * rng.gaussian());
                }
                labels.push(c);
            }
        }
        (Mat::from_vec(n_per * 3, 4, data), labels)
    }

    #[test]
    fn separable_blobs_high_accuracy() {
        let (train_x, train_y) = blob_data(40, 1);
        let (test_x, test_y) = blob_data(20, 2);
        let forest = RandomForest::fit(&train_x, &train_y, 3, &RandomForestConfig::default());
        let acc = (0..test_x.rows)
            .filter(|&i| forest.predict(test_x.row(i)) == test_y[i])
            .count() as f64
            / test_x.rows as f64;
        assert!(acc > 0.95, "accuracy {acc}");
    }

    #[test]
    fn constant_features_fall_back_to_majority() {
        let x = Mat::zeros(20, 3);
        let y: Vec<usize> = (0..20).map(|i| usize::from(i < 14)).collect();
        let forest = RandomForest::fit(&x, &y, 2, &RandomForestConfig::default());
        // Majority class is 1 (14 of 20 labels are `1`).
        assert_eq!(forest.predict(&[0.0, 0.0, 0.0]), 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = blob_data(15, 3);
        let cfg = RandomForestConfig { seed: 5, ..Default::default() };
        let f1 = RandomForest::fit(&x, &y, 3, &cfg);
        let f2 = RandomForest::fit(&x, &y, 3, &cfg);
        for i in 0..x.rows {
            assert_eq!(f1.predict(x.row(i)), f2.predict(x.row(i)));
        }
    }

    #[test]
    fn better_than_chance_on_noisy_labels() {
        let (x, y) = blob_data(30, 4);
        let forest = RandomForest::fit(&x, &y, 3, &RandomForestConfig::default());
        let acc = (0..x.rows)
            .filter(|&i| forest.predict(x.row(i)) == y[i])
            .count() as f64
            / x.rows as f64;
        assert!(acc > 0.6);
    }
}
