//! Graph-classification baselines for paper Table 8: Vertex Histogram
//! (VH), Random Walk (RW), Shortest-Path (SP, with optional
//! Weisfeiler–Lehman refinement → WL-SP), and the Feature-Based spectral
//! method (FB, de Lara & Pineau 2018). Each produces a fixed-length
//! feature vector per labeled graph; classification happens downstream in
//! the shared random forest (our SVM substitute, documented in DESIGN.md).

use crate::graph::{bfs_levels, CsrGraph};
use crate::linalg::{eigh_tridiagonal, Mat};

/// A node-labeled graph instance.
#[derive(Clone, Debug)]
pub struct LabeledGraph {
    pub graph: CsrGraph,
    pub labels: Vec<usize>,
    /// Optional 3-D node embeddings (used by the RFD kernel variant).
    pub positions: Vec<[f64; 3]>,
}

/// Vertex-histogram features: normalized label counts.
pub fn vh_features(g: &LabeledGraph, num_labels: usize) -> Vec<f64> {
    let mut h = vec![0.0; num_labels];
    for &l in &g.labels {
        h[l.min(num_labels - 1)] += 1.0;
    }
    let n = g.labels.len().max(1) as f64;
    for x in h.iter_mut() {
        *x /= n;
    }
    h
}

/// Random-walk features: total weight of walks of length 1..=k,
/// normalized by n² (trace-free variant: sum over all pairs).
pub fn rw_features(g: &LabeledGraph, k: usize) -> Vec<f64> {
    let n = g.graph.n;
    let mut x = vec![1.0; n];
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        x = g.graph.adj_matvec_multi(&x, 1);
        out.push(x.iter().sum::<f64>() / (n * n).max(1) as f64);
    }
    out
}

/// Shortest-path features: histogram of pairwise hop distances, bucketed
/// to `buckets` (unreachable pairs go to the last bucket), normalized.
pub fn sp_features(g: &LabeledGraph, buckets: usize) -> Vec<f64> {
    let n = g.graph.n;
    let mut h = vec![0.0; buckets];
    for v in 0..n {
        let lv = bfs_levels(&g.graph, v);
        for (u, &l) in lv.iter().enumerate() {
            if u == v {
                continue;
            }
            let b = if l == usize::MAX { buckets - 1 } else { l.min(buckets - 1) };
            h[b] += 1.0;
        }
    }
    let total: f64 = h.iter().sum::<f64>().max(1.0);
    for x in h.iter_mut() {
        *x /= total;
    }
    h
}

/// One round of Weisfeiler–Lehman label refinement: new label = hash of
/// (own label, sorted multiset of neighbor labels).
pub fn wl_refine(g: &LabeledGraph) -> Vec<usize> {
    let mut table: std::collections::HashMap<(usize, Vec<usize>), usize> =
        std::collections::HashMap::new();
    let mut out = Vec::with_capacity(g.labels.len());
    for v in 0..g.graph.n {
        let mut nbr: Vec<usize> = g.graph.neighbors(v).map(|(u, _)| g.labels[u]).collect();
        nbr.sort_unstable();
        let key = (g.labels[v], nbr);
        let next = table.len();
        let id = *table.entry(key).or_insert(next);
        out.push(id);
    }
    out
}

/// WL-SP features: one WL refinement, then label-pair-aware shortest-path
/// histogram compressed to `buckets × label_hash_buckets`.
pub fn wl_sp_features(g: &LabeledGraph, buckets: usize, label_buckets: usize) -> Vec<f64> {
    let wl = wl_refine(g);
    let n = g.graph.n;
    let mut h = vec![0.0; buckets * label_buckets];
    for v in 0..n {
        let lv = bfs_levels(&g.graph, v);
        for (u, &l) in lv.iter().enumerate() {
            if u == v || l == usize::MAX {
                continue;
            }
            let b = l.min(buckets - 1);
            let lb = (wl[v] ^ wl[u].rotate_left(7)) % label_buckets;
            h[b * label_buckets + lb] += 1.0;
        }
    }
    let total: f64 = h.iter().sum::<f64>().max(1.0);
    for x in h.iter_mut() {
        *x /= total;
    }
    h
}

/// Feature-based method (de Lara & Pineau 2018): the `k` smallest
/// eigenvalues of the normalized graph Laplacian.
pub fn fb_features(g: &LabeledGraph, k: usize) -> Vec<f64> {
    let n = g.graph.n;
    let mut lap = Mat::zeros(n, n);
    let deg: Vec<f64> = (0..n)
        .map(|v| g.graph.neighbors(v).map(|(_, w)| w).sum::<f64>().max(1e-12))
        .collect();
    for v in 0..n {
        lap[(v, v)] = 1.0;
        for (u, w) in g.graph.neighbors(v) {
            lap[(v, u)] -= w / (deg[v] * deg[u]).sqrt();
        }
    }
    let mut eigs = eigh_tridiagonal(&lap);
    eigs.truncate(k);
    while eigs.len() < k {
        eigs.push(2.0); // λ_max(normalized L) ≤ 2: pad out-of-band
    }
    eigs
}

/// RFD spectral features over the node positions (the paper's method:
/// nodes as points in R³, ε-NN kernel eigenvalues).
pub fn rfd_graph_features(
    g: &LabeledGraph,
    cfg: &crate::integrators::rfd::RfdConfig,
    k: usize,
) -> Vec<f64> {
    let pc = crate::pointcloud::PointCloud::new(g.positions.clone());
    super::rfd_spectral_features(&pc, cfg, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ring(n: usize, label_period: usize) -> LabeledGraph {
        let edges: Vec<(usize, usize, f64)> =
            (0..n).map(|i| (i, (i + 1) % n, 1.0)).collect();
        LabeledGraph {
            graph: CsrGraph::from_edges(n, &edges),
            labels: (0..n).map(|i| i % label_period).collect(),
            positions: (0..n)
                .map(|i| {
                    let t = 2.0 * std::f64::consts::PI * i as f64 / n as f64;
                    [t.cos(), t.sin(), 0.0]
                })
                .collect(),
        }
    }

    #[test]
    fn vh_sums_to_one() {
        let g = ring(12, 3);
        let f = vh_features(&g, 4);
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((f[0] - 4.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn rw_monotone_on_ring() {
        // 2-regular ring: walk counts of length k are exactly n·2^k.
        let g = ring(10, 2);
        let f = rw_features(&g, 4);
        for (k, &x) in f.iter().enumerate() {
            let want = 10.0 * 2f64.powi(k as i32 + 1) / 100.0;
            assert!((x - want).abs() < 1e-9, "k={k}: {x} vs {want}");
        }
    }

    #[test]
    fn sp_histogram_normalized() {
        let g = ring(8, 2);
        let f = sp_features(&g, 6);
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wl_distinguishes_degree_patterns() {
        // A star and a ring of the same size with uniform labels get
        // different WL refinements.
        let ring_g = ring(6, 1);
        let star_edges: Vec<(usize, usize, f64)> = (1..6).map(|i| (0, i, 1.0)).collect();
        let star = LabeledGraph {
            graph: CsrGraph::from_edges(6, &star_edges),
            labels: vec![0; 6],
            positions: vec![[0.0; 3]; 6],
        };
        let wl_ring = wl_refine(&ring_g);
        let wl_star = wl_refine(&star);
        // Ring: all nodes identical; star: center differs from leaves.
        assert!(wl_ring.iter().all(|&l| l == wl_ring[0]));
        assert!(wl_star[1..].iter().all(|&l| l == wl_star[1]));
        assert_ne!(wl_star[0], wl_star[1]);
    }

    #[test]
    fn fb_spectrum_in_band() {
        let g = ring(10, 2);
        let f = fb_features(&g, 5);
        assert_eq!(f.len(), 5);
        for &x in &f {
            assert!((-1e-9..=2.0 + 1e-9).contains(&x), "normalized eig {x}");
        }
        assert!(f[0].abs() < 1e-8, "smallest normalized-Laplacian eig is 0");
    }

    #[test]
    fn feature_vectors_distinguish_families() {
        let mut rng = Rng::new(1);
        let _ = &mut rng;
        let a = ring(12, 2);
        let star_edges: Vec<(usize, usize, f64)> = (1..12).map(|i| (0, i, 1.0)).collect();
        let b = LabeledGraph {
            graph: CsrGraph::from_edges(12, &star_edges),
            labels: vec![0; 12],
            positions: vec![[0.0; 3]; 12],
        };
        let fa = sp_features(&a, 6);
        let fb_ = sp_features(&b, 6);
        let diff: f64 = fa.iter().zip(&fb_).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 0.1);
    }
}
