//! Weighted undirected graph substrate: CSR storage, the consolidated
//! shortest-path / BFS kernels and batched parallel distance engine
//! ([`distances`] — one Dijkstra implementation behind every caller;
//! [`dijkstra`], [`multi_source_dijkstra`], [`dijkstra_bounded`], and
//! [`bfs_levels`] are thin compatibility re-exports over it), connected
//! components, induced subgraphs, Laplacians, and sparse matvec —
//! everything SF, the tree embeddings, and the diffusion baselines need.

mod csr;
pub mod distances;
mod shortest_path;

pub use csr::CsrGraph;
pub use shortest_path::{bfs_levels, dijkstra, dijkstra_bounded, multi_source_dijkstra};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integration_path_graph() {
        // 0 -1.0- 1 -2.0- 2
        let g = CsrGraph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0)]);
        let d = dijkstra(&g, 0);
        assert_eq!(d, vec![0.0, 1.0, 3.0]);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.num_components(), 1);
    }
}
