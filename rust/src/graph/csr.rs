//! Compressed-sparse-row undirected weighted graph.

/// Undirected weighted graph in CSR form. Each undirected edge is stored
/// twice (once per endpoint); weights must be positive for shortest paths.
#[derive(Clone, Debug)]
pub struct CsrGraph {
    pub n: usize,
    pub offsets: Vec<usize>,
    pub targets: Vec<u32>,
    pub weights: Vec<f64>,
}

impl CsrGraph {
    /// Builds from an undirected edge list `(u, v, w)`. Self-loops are
    /// dropped; parallel edges are kept (harmless for Dijkstra, summed by
    /// the Laplacian/matvec consumers).
    pub fn from_edges(n: usize, edges: &[(usize, usize, f64)]) -> Self {
        let mut deg = vec![0usize; n];
        for &(u, v, _) in edges {
            if u == v {
                continue;
            }
            assert!(u < n && v < n, "edge ({u},{v}) out of range n={n}");
            deg[u] += 1;
            deg[v] += 1;
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let m2 = offsets[n];
        let mut targets = vec![0u32; m2];
        let mut weights = vec![0.0; m2];
        let mut cursor = offsets.clone();
        for &(u, v, w) in edges {
            if u == v {
                continue;
            }
            targets[cursor[u]] = v as u32;
            weights[cursor[u]] = w;
            cursor[u] += 1;
            targets[cursor[v]] = u as u32;
            weights[cursor[v]] = w;
            cursor[v] += 1;
        }
        CsrGraph { n, offsets, targets, weights }
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.targets.len() / 2
    }

    /// Estimated resident heap bytes of the CSR arrays (cache weight
    /// accounting for graph-holding integrators and registered scenes).
    pub fn resident_bytes(&self) -> usize {
        self.offsets.len() * std::mem::size_of::<usize>()
            + self.targets.len() * std::mem::size_of::<u32>()
            + self.weights.len() * std::mem::size_of::<f64>()
    }

    #[inline]
    pub fn neighbors(&self, v: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.offsets[v];
        let hi = self.offsets[v + 1];
        self.targets[lo..hi]
            .iter()
            .zip(&self.weights[lo..hi])
            .map(|(&t, &w)| (t as usize, w))
    }

    pub fn degree(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    /// Sparse matvec with the weighted adjacency matrix: `out = W_G · x`
    /// where `x` has `d` interleaved columns (row-major `n × d`).
    pub fn adj_matvec_multi(&self, x: &[f64], d: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.n * d];
        self.adj_matvec_multi_into(x, d, &mut out);
        out
    }

    /// Allocation-free variant of [`CsrGraph::adj_matvec_multi`]:
    /// overwrites the caller-held `out`.
    pub fn adj_matvec_multi_into(&self, x: &[f64], d: usize, out: &mut [f64]) {
        assert_eq!(x.len(), self.n * d);
        assert_eq!(out.len(), self.n * d);
        out.fill(0.0);
        for v in 0..self.n {
            let orow = &mut out[v * d..(v + 1) * d];
            for (u, w) in self.neighbors(v) {
                let xrow = &x[u * d..(u + 1) * d];
                for (o, &xv) in orow.iter_mut().zip(xrow) {
                    *o += w * xv;
                }
            }
        }
    }

    /// Graph Laplacian matvec: `out = (D − W) x`, multi-column.
    pub fn laplacian_matvec_multi(&self, x: &[f64], d: usize) -> Vec<f64> {
        assert_eq!(x.len(), self.n * d);
        let mut out = vec![0.0; self.n * d];
        for v in 0..self.n {
            let mut wsum = 0.0;
            let (lo, hi) = (self.offsets[v], self.offsets[v + 1]);
            for i in lo..hi {
                wsum += self.weights[i];
            }
            let orow = &mut out[v * d..(v + 1) * d];
            let xv = &x[v * d..(v + 1) * d];
            for (o, &a) in orow.iter_mut().zip(xv) {
                *o += wsum * a;
            }
            for i in lo..hi {
                let u = self.targets[i] as usize;
                let w = self.weights[i];
                let xu = &x[u * d..(u + 1) * d];
                for (o, &a) in orow.iter_mut().zip(xu) {
                    *o -= w * a;
                }
            }
        }
        out
    }

    /// Connected component id per vertex (BFS flood fill).
    pub fn components(&self) -> Vec<usize> {
        let mut comp = vec![usize::MAX; self.n];
        let mut next = 0usize;
        let mut queue = std::collections::VecDeque::new();
        for s in 0..self.n {
            if comp[s] != usize::MAX {
                continue;
            }
            comp[s] = next;
            queue.push_back(s);
            while let Some(v) = queue.pop_front() {
                for (u, _) in self.neighbors(v) {
                    if comp[u] == usize::MAX {
                        comp[u] = next;
                        queue.push_back(u);
                    }
                }
            }
            next += 1;
        }
        comp
    }

    pub fn num_components(&self) -> usize {
        let c = self.components();
        c.iter().copied().max().map(|m| m + 1).unwrap_or(0)
    }

    /// Induced subgraph on `nodes` (must be duplicate-free). Returns the
    /// subgraph plus the mapping `sub-index → original-index` (which is
    /// just `nodes` itself, echoed for call-site clarity).
    pub fn induced(&self, nodes: &[usize]) -> (CsrGraph, Vec<usize>) {
        let mut local = vec![u32::MAX; self.n];
        for (i, &v) in nodes.iter().enumerate() {
            debug_assert!(local[v] == u32::MAX, "duplicate node {v}");
            local[v] = i as u32;
        }
        let mut edges = Vec::new();
        for (i, &v) in nodes.iter().enumerate() {
            for (u, w) in self.neighbors(v) {
                let lu = local[u];
                if lu != u32::MAX && (lu as usize) > i {
                    edges.push((i, lu as usize, w));
                }
            }
        }
        (CsrGraph::from_edges(nodes.len(), &edges), nodes.to_vec())
    }

    /// Total edge weight (each undirected edge counted once).
    pub fn total_weight(&self) -> f64 {
        self.weights.iter().sum::<f64>() / 2.0
    }

    /// Minimum edge weight (∞ for edgeless graphs).
    pub fn min_edge_weight(&self) -> f64 {
        self.weights.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn square() -> CsrGraph {
        CsrGraph::from_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 0, 1.0)])
    }

    #[test]
    fn csr_symmetry() {
        let g = square();
        assert_eq!(g.num_edges(), 4);
        for v in 0..4 {
            assert_eq!(g.degree(v), 2);
            for (u, _) in g.neighbors(v) {
                assert!(g.neighbors(u).any(|(t, _)| t == v));
            }
        }
    }

    #[test]
    fn self_loops_dropped() {
        let g = CsrGraph::from_edges(2, &[(0, 0, 5.0), (0, 1, 1.0)]);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.degree(0), 1);
    }

    #[test]
    fn adj_matvec() {
        let g = square();
        // x = e_0; Wx puts weight on neighbors 1 and 3.
        let mut x = vec![0.0; 4];
        x[0] = 1.0;
        let y = g.adj_matvec_multi(&x, 1);
        assert_eq!(y, vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn laplacian_constant_nullspace() {
        let g = square();
        let x = vec![3.5; 4];
        let y = g.laplacian_matvec_multi(&x, 1);
        assert!(y.iter().all(|v| v.abs() < 1e-12));
    }

    #[test]
    fn components_and_induced() {
        let g = CsrGraph::from_edges(5, &[(0, 1, 1.0), (1, 2, 1.0), (3, 4, 1.0)]);
        assert_eq!(g.num_components(), 2);
        let (sub, map) = g.induced(&[0, 2, 1]);
        assert_eq!(sub.n, 3);
        assert_eq!(sub.num_edges(), 2);
        assert_eq!(map, vec![0, 2, 1]);
    }

    #[test]
    fn multi_column_matvec_matches_single() {
        let g = square();
        let x: Vec<f64> = (0..8).map(|i| i as f64).collect(); // 4×2
        let y = g.adj_matvec_multi(&x, 2);
        for c in 0..2 {
            let xc: Vec<f64> = (0..4).map(|r| x[r * 2 + c]).collect();
            let yc = g.adj_matvec_multi(&xc, 1);
            for r in 0..4 {
                assert_eq!(y[r * 2 + c], yc[r]);
            }
        }
    }
}
