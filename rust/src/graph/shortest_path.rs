//! Thin compatibility re-exports over the consolidated shortest-path
//! kernels in [`super::distances`].
//!
//! The seed kept two Dijkstra implementations: the batched scratch-reuse
//! engine in `distances` and a second heap-per-call one here (plus a
//! `HashMap`-based bounded variant). PR 5 consolidated them — every
//! caller now runs through the `distances` kernels (flat `(f64, u32)`
//! heap, lazy `O(|touched|)` reset), so there is exactly one Dijkstra to
//! optimize. This module survives as the stable import path
//! (`crate::graph::{dijkstra, multi_source_dijkstra, dijkstra_bounded,
//! bfs_levels}`); the behavioral contracts are pinned by the tests below.

pub use super::distances::{bfs_levels, dijkstra, dijkstra_bounded, multi_source_dijkstra};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::CsrGraph;

    fn grid3x3() -> CsrGraph {
        // 3x3 grid, unit weights; index = r*3+c.
        let mut e = Vec::new();
        for r in 0..3 {
            for c in 0..3 {
                let v = r * 3 + c;
                if c + 1 < 3 {
                    e.push((v, v + 1, 1.0));
                }
                if r + 1 < 3 {
                    e.push((v, v + 3, 1.0));
                }
            }
        }
        CsrGraph::from_edges(9, &e)
    }

    #[test]
    fn dijkstra_grid_manhattan() {
        let g = grid3x3();
        let d = dijkstra(&g, 0);
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(d[r * 3 + c], (r + c) as f64);
            }
        }
    }

    #[test]
    fn dijkstra_weighted_shortcut() {
        // 0-1 (10), 0-2 (1), 2-1 (1): shortest 0→1 is 2 via 2.
        let g = CsrGraph::from_edges(3, &[(0, 1, 10.0), (0, 2, 1.0), (2, 1, 1.0)]);
        assert_eq!(dijkstra(&g, 0)[1], 2.0);
    }

    #[test]
    fn unreachable_is_infinite() {
        let g = CsrGraph::from_edges(3, &[(0, 1, 1.0)]);
        let d = dijkstra(&g, 0);
        assert!(d[2].is_infinite());
    }

    #[test]
    fn multi_source_nearest() {
        let g = grid3x3();
        let d = multi_source_dijkstra(&g, &[0, 8]);
        assert_eq!(d[0], 0.0);
        assert_eq!(d[8], 0.0);
        assert_eq!(d[4], 2.0); // center equidistant
    }

    #[test]
    fn bounded_respects_radius() {
        let g = grid3x3();
        let reached = dijkstra_bounded(&g, 0, 1.5);
        let nodes: std::collections::HashSet<usize> =
            reached.iter().map(|&(v, _)| v).collect();
        assert_eq!(nodes, [0, 1, 3].into_iter().collect());
    }

    #[test]
    fn bounded_output_is_distance_sorted() {
        let g = grid3x3();
        let reached = dijkstra_bounded(&g, 4, 2.5);
        for w in reached.windows(2) {
            assert!(
                (w[0].1, w[0].0) <= (w[1].1, w[1].0),
                "bounded output must be (distance, vertex)-sorted: {reached:?}"
            );
        }
        // Distances must match the unbounded run on the reached set.
        let full = dijkstra(&g, 4);
        for &(v, d) in &reached {
            assert_eq!(d, full[v]);
        }
    }

    #[test]
    fn bfs_matches_dijkstra_on_unit_weights() {
        let g = grid3x3();
        let lv = bfs_levels(&g, 4);
        let d = dijkstra(&g, 4);
        for v in 0..9 {
            assert_eq!(lv[v] as f64, d[v]);
        }
    }
}
