//! Shortest paths: binary-heap Dijkstra (single-, multi-source, and
//! radius-bounded variants) and unweighted BFS levels. These are SF's
//! pre-processing workhorses (paper App. A.2 uses one Dijkstra run per
//! separator vertex per recursion level).

use super::CsrGraph;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(PartialEq)]
struct HeapItem {
    dist: f64,
    node: usize,
}

impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed comparison; ties broken by node id for
        // determinism.
        other
            .dist
            .partial_cmp(&self.dist)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.node.cmp(&self.node))
    }
}

/// Single-source Dijkstra. Unreachable vertices get `f64::INFINITY`.
///
/// One-shot convenience over [`super::distances::SsspScratch`]; loops
/// over many sources should use [`super::distances`] instead, which
/// reuses the scratch across sources and parallelizes.
pub fn dijkstra(g: &CsrGraph, source: usize) -> Vec<f64> {
    multi_source_dijkstra(g, &[source])
}

/// Multi-source Dijkstra: distance to the *nearest* source.
pub fn multi_source_dijkstra(g: &CsrGraph, sources: &[usize]) -> Vec<f64> {
    let mut scratch = super::distances::SsspScratch::new(g.n);
    scratch.run(g, sources);
    scratch.into_dist()
}

/// Dijkstra truncated at `radius`: vertices farther than `radius` keep
/// `INFINITY` and the search never expands past them (used by the FRT/
/// Bartal ball-growing and by local interpolation windows).
pub fn dijkstra_bounded(g: &CsrGraph, source: usize, radius: f64) -> Vec<(usize, f64)> {
    let mut dist = std::collections::HashMap::new();
    let mut heap = BinaryHeap::new();
    dist.insert(source, 0.0);
    heap.push(HeapItem { dist: 0.0, node: source });
    let mut out = Vec::new();
    while let Some(HeapItem { dist: d, node: v }) = heap.pop() {
        if d > *dist.get(&v).unwrap_or(&f64::INFINITY) {
            continue;
        }
        out.push((v, d));
        for (u, w) in g.neighbors(v) {
            let nd = d + w;
            if nd <= radius && nd < *dist.get(&u).unwrap_or(&f64::INFINITY) {
                dist.insert(u, nd);
                heap.push(HeapItem { dist: nd, node: u });
            }
        }
    }
    out
}

/// Unweighted BFS levels from `source` (hop counts; `usize::MAX` if
/// unreachable).
pub fn bfs_levels(g: &CsrGraph, source: usize) -> Vec<usize> {
    let mut level = vec![usize::MAX; g.n];
    let mut queue = std::collections::VecDeque::new();
    level[source] = 0;
    queue.push_back(source);
    while let Some(v) = queue.pop_front() {
        for (u, _) in g.neighbors(v) {
            if level[u] == usize::MAX {
                level[u] = level[v] + 1;
                queue.push_back(u);
            }
        }
    }
    level
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid3x3() -> CsrGraph {
        // 3x3 grid, unit weights; index = r*3+c.
        let mut e = Vec::new();
        for r in 0..3 {
            for c in 0..3 {
                let v = r * 3 + c;
                if c + 1 < 3 {
                    e.push((v, v + 1, 1.0));
                }
                if r + 1 < 3 {
                    e.push((v, v + 3, 1.0));
                }
            }
        }
        CsrGraph::from_edges(9, &e)
    }

    #[test]
    fn dijkstra_grid_manhattan() {
        let g = grid3x3();
        let d = dijkstra(&g, 0);
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(d[r * 3 + c], (r + c) as f64);
            }
        }
    }

    #[test]
    fn dijkstra_weighted_shortcut() {
        // 0-1 (10), 0-2 (1), 2-1 (1): shortest 0→1 is 2 via 2.
        let g = CsrGraph::from_edges(3, &[(0, 1, 10.0), (0, 2, 1.0), (2, 1, 1.0)]);
        assert_eq!(dijkstra(&g, 0)[1], 2.0);
    }

    #[test]
    fn unreachable_is_infinite() {
        let g = CsrGraph::from_edges(3, &[(0, 1, 1.0)]);
        let d = dijkstra(&g, 0);
        assert!(d[2].is_infinite());
    }

    #[test]
    fn multi_source_nearest() {
        let g = grid3x3();
        let d = multi_source_dijkstra(&g, &[0, 8]);
        assert_eq!(d[0], 0.0);
        assert_eq!(d[8], 0.0);
        assert_eq!(d[4], 2.0); // center equidistant
    }

    #[test]
    fn bounded_respects_radius() {
        let g = grid3x3();
        let reached = dijkstra_bounded(&g, 0, 1.5);
        let nodes: std::collections::HashSet<usize> =
            reached.iter().map(|&(v, _)| v).collect();
        assert_eq!(nodes, [0, 1, 3].into_iter().collect());
    }

    #[test]
    fn bfs_matches_dijkstra_on_unit_weights() {
        let g = grid3x3();
        let lv = bfs_levels(&g, 4);
        let d = dijkstra(&g, 4);
        for v in 0..9 {
            assert_eq!(lv[v] as f64, d[v]);
        }
    }
}
