//! Batched parallel graph-distance engine.
//!
//! Every many-source shortest-path workload in the library (brute-force
//! kernel materialization, SF's per-separator sweeps, leaf all-pairs, GW
//! shortest-path structure matrices, interpolation baselines) used to run
//! one independent [`super::dijkstra`] per source — allocating a fresh
//! distance array and a fresh binary heap every time. This module runs the
//! same algorithm through per-thread reusable scratch:
//!
//! * [`SsspScratch`] — a distance array reset lazily in `O(|touched|)`
//!   per run (not `O(N)`), a reusable flat binary heap of `(f64, u32)`
//!   pairs (no per-push allocation, no 16-byte `partial_cmp` wrapper), and
//!   an optional nearest-source assignment channel.
//! * [`for_each_source`] — dynamic work-stealing over a source list with
//!   one scratch per worker thread; the callback sees each dense distance
//!   row exactly once.
//! * [`distance_matrix`] / [`rows`] — the common materializations.
//! * [`nearest_sources`] — multi-source Voronoi: distance to, and index
//!   of, the nearest source per vertex.

use super::CsrGraph;
use crate::linalg::Mat;
use crate::util::par;
use crate::util::simd::{self, Kern};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Flat binary min-heap push on `(dist, node)` pairs.
#[inline]
fn heap_push(h: &mut Vec<(f64, u32)>, item: (f64, u32)) {
    h.push(item);
    let mut i = h.len() - 1;
    while i > 0 {
        let parent = (i - 1) / 2;
        if h[parent].0 <= h[i].0 {
            break;
        }
        h.swap(i, parent);
        i = parent;
    }
}

/// Flat binary min-heap pop.
#[inline]
fn heap_pop(h: &mut Vec<(f64, u32)>) -> Option<(f64, u32)> {
    let len = h.len();
    if len == 0 {
        return None;
    }
    h.swap(0, len - 1);
    let top = h.pop().unwrap();
    let n = h.len();
    let mut i = 0;
    loop {
        let l = 2 * i + 1;
        if l >= n {
            break;
        }
        let r = l + 1;
        let smallest = if r < n && h[r].0 < h[l].0 { r } else { l };
        if h[i].0 <= h[smallest].0 {
            break;
        }
        h.swap(i, smallest);
        i = smallest;
    }
    Some(top)
}

/// Reusable single-/multi-source Dijkstra state for one graph size.
/// Construction is the only `O(N)` allocation; every subsequent run costs
/// `O(|reached| log |reached|)` with zero allocation beyond heap growth on
/// the first run.
pub struct SsspScratch {
    dist: Vec<f64>,
    /// Vertices whose `dist` entry differs from `INFINITY` (reset list).
    touched: Vec<u32>,
    heap: Vec<(f64, u32)>,
}

impl SsspScratch {
    pub fn new(n: usize) -> Self {
        SsspScratch { dist: vec![f64::INFINITY; n], touched: Vec::new(), heap: Vec::new() }
    }

    /// Nearest-source Dijkstra from `sources`. Returns the dense distance
    /// row (`INFINITY` = unreachable), valid until the next run on this
    /// scratch.
    pub fn run(&mut self, g: &CsrGraph, sources: &[usize]) -> &[f64] {
        self.run_impl(g, sources, None);
        &self.dist
    }

    /// Like [`SsspScratch::run`], additionally recording in `assign[v]`
    /// the index (into `sources`) of the nearest source reaching `v`.
    /// Entries for unreached vertices are left untouched — pre-fill with
    /// a sentinel.
    pub fn run_with_assignment(
        &mut self,
        g: &CsrGraph,
        sources: &[usize],
        assign: &mut [u32],
    ) -> &[f64] {
        self.run_impl(g, sources, Some(assign));
        &self.dist
    }

    /// Consumes the scratch, yielding the final distance row (the
    /// one-shot compatibility path for [`super::multi_source_dijkstra`]).
    pub fn into_dist(self) -> Vec<f64> {
        self.dist
    }

    /// Radius-bounded single-source Dijkstra on this scratch: vertices
    /// farther than `radius` are never expanded (or reported). Returns
    /// the reached `(vertex, distance)` pairs sorted by
    /// `(distance, vertex)` — deterministic regardless of heap internals.
    /// This is the ball-growing kernel the FRT/Bartal tree embeddings
    /// call in a tight loop: reusing one scratch across calls replaces
    /// the old per-call `HashMap` + `BinaryHeap` allocations with a lazy
    /// `O(|touched|)` reset.
    pub fn run_bounded(
        &mut self,
        g: &CsrGraph,
        source: usize,
        radius: f64,
    ) -> Vec<(usize, f64)> {
        assert_eq!(self.dist.len(), g.n, "scratch sized for a different graph");
        for &v in &self.touched {
            self.dist[v as usize] = f64::INFINITY;
        }
        self.touched.clear();
        self.heap.clear();
        self.dist[source] = 0.0;
        self.touched.push(source as u32);
        heap_push(&mut self.heap, (0.0, source as u32));
        let mut out = Vec::new();
        while let Some((d, v)) = heap_pop(&mut self.heap) {
            let vu = v as usize;
            if d > self.dist[vu] {
                continue; // stale entry (lazy deletion)
            }
            out.push((vu, d));
            let (lo, hi) = (g.offsets[vu], g.offsets[vu + 1]);
            for e in lo..hi {
                let u = g.targets[e] as usize;
                let nd = d + g.weights[e];
                if nd <= radius && nd < self.dist[u] {
                    if self.dist[u] == f64::INFINITY {
                        self.touched.push(u as u32);
                    }
                    self.dist[u] = nd;
                    heap_push(&mut self.heap, (nd, u as u32));
                }
            }
        }
        out.sort_by(|a, b| {
            a.1.partial_cmp(&b.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
        out
    }

    fn run_impl(&mut self, g: &CsrGraph, sources: &[usize], mut assign: Option<&mut [u32]>) {
        assert_eq!(self.dist.len(), g.n, "scratch sized for a different graph");
        if let Some(a) = assign.as_deref() {
            assert_eq!(a.len(), g.n);
        }
        // Lazy reset: only entries the previous run touched.
        for &v in &self.touched {
            self.dist[v as usize] = f64::INFINITY;
        }
        self.touched.clear();
        self.heap.clear();
        for (si, &s) in sources.iter().enumerate() {
            if self.dist[s] > 0.0 {
                self.dist[s] = 0.0;
                self.touched.push(s as u32);
                if let Some(a) = assign.as_deref_mut() {
                    a[s] = si as u32;
                }
                heap_push(&mut self.heap, (0.0, s as u32));
            }
        }
        // SIMD prefilter needs i32-safe gather indices; graphs beyond
        // 2^31 vertices (never in practice) fall back to scalar.
        let kern = if g.n <= i32::MAX as usize { simd::kern() } else { Kern::Scalar };
        while let Some((d, v)) = heap_pop(&mut self.heap) {
            let vu = v as usize;
            if d > self.dist[vu] {
                continue; // stale entry (lazy deletion)
            }
            let (lo, hi) = (g.offsets[vu], g.offsets[vu + 1]);
            let mut e = lo;
            // AVX2 prefilter over 4 edges at a time: gather the 4 live
            // distances, compute nd = d + w (exactly-rounded vector add —
            // the same f64 each scalar lane would compute), and skip the
            // whole chunk when no lane improves. This is sound even with
            // duplicate targets in one chunk: `dist` only ever decreases,
            // so nd ≥ gathered ⟹ nd ≥ live ⟹ the scalar check would
            // fail too. Lanes that *do* pass re-run the exact scalar
            // relaxation in lane order (with the live distance), so the
            // heap evolves bitwise-identically to the scalar path.
            #[cfg(target_arch = "x86_64")]
            if kern == Kern::Avx2 {
                while e + 4 <= hi {
                    // SAFETY: AVX2 is detected and 4 targets/weights
                    // starting at `e` are in bounds (e + 4 <= hi).
                    let mask = unsafe {
                        relax_mask_avx2(&self.dist, &g.targets[e..], &g.weights[e..], d)
                    };
                    if mask != 0 {
                        for lane in 0..4usize {
                            if mask & (1 << lane) != 0 {
                                let ei = e + lane;
                                let u = g.targets[ei] as usize;
                                let nd = d + g.weights[ei];
                                relax_edge(
                                    &mut self.dist,
                                    &mut self.touched,
                                    &mut self.heap,
                                    &mut assign,
                                    vu,
                                    u,
                                    nd,
                                );
                            }
                        }
                    }
                    e += 4;
                }
            }
            let _ = kern;
            while e < hi {
                let u = g.targets[e] as usize;
                let nd = d + g.weights[e];
                relax_edge(
                    &mut self.dist,
                    &mut self.touched,
                    &mut self.heap,
                    &mut assign,
                    vu,
                    u,
                    nd,
                );
                e += 1;
            }
        }
    }
}

/// The scalar relaxation — the oracle the AVX2 prefilter defers to. Both
/// the tail loop and every prefilter-passing lane run exactly this body
/// against the live `dist`, so SIMD on/off cannot change any committed
/// distance, touch order, or heap push sequence.
#[inline]
fn relax_edge(
    dist: &mut [f64],
    touched: &mut Vec<u32>,
    heap: &mut Vec<(f64, u32)>,
    assign: &mut Option<&mut [u32]>,
    vu: usize,
    u: usize,
    nd: f64,
) {
    if nd < dist[u] {
        if dist[u] == f64::INFINITY {
            touched.push(u as u32);
        }
        dist[u] = nd;
        if let Some(a) = assign.as_deref_mut() {
            let label = a[vu];
            a[u] = label;
        }
        heap_push(heap, (nd, u as u32));
    }
}

/// Lane mask of edges whose tentative distance `d + w[lane]` beats the
/// gathered (possibly stale-high, never stale-low) current distance of
/// its target. `_CMP_LT_OQ` matches scalar `<` exactly, including the
/// all-false behaviour on NaN weights.
///
/// # Safety
/// Requires AVX2; `targets`/`weights` must hold ≥ 4 entries and every
/// target must index into `dist` (CSR invariant).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn relax_mask_avx2(dist: &[f64], targets: &[u32], weights: &[f64], d: f64) -> i32 {
    use std::arch::x86_64::*;
    debug_assert!(targets.len() >= 4 && weights.len() >= 4);
    let idx = _mm_loadu_si128(targets.as_ptr() as *const __m128i);
    let cur = _mm256_i32gather_pd::<8>(dist.as_ptr(), idx);
    let nd = _mm256_add_pd(_mm256_set1_pd(d), _mm256_loadu_pd(weights.as_ptr()));
    let lt = _mm256_cmp_pd::<_CMP_LT_OQ>(nd, cur);
    _mm256_movemask_pd(lt)
}

/// Single-source Dijkstra. Unreachable vertices get `f64::INFINITY`.
///
/// One-shot convenience over [`SsspScratch`]; loops over many sources
/// should use [`for_each_source`] / a reused scratch instead.
pub fn dijkstra(g: &CsrGraph, source: usize) -> Vec<f64> {
    multi_source_dijkstra(g, &[source])
}

/// Multi-source Dijkstra: distance to the *nearest* source.
pub fn multi_source_dijkstra(g: &CsrGraph, sources: &[usize]) -> Vec<f64> {
    let mut scratch = SsspScratch::new(g.n);
    scratch.run(g, sources);
    scratch.into_dist()
}

/// Dijkstra truncated at `radius`: vertices farther than `radius` keep
/// `INFINITY` and the search never expands past them (used by the FRT/
/// Bartal ball-growing and by local interpolation windows). One-shot
/// convenience over [`SsspScratch::run_bounded`] — tight loops should
/// hold a scratch and call `run_bounded` directly.
pub fn dijkstra_bounded(g: &CsrGraph, source: usize, radius: f64) -> Vec<(usize, f64)> {
    SsspScratch::new(g.n).run_bounded(g, source, radius)
}

/// Unweighted BFS levels from `source` (hop counts; `usize::MAX` if
/// unreachable).
pub fn bfs_levels(g: &CsrGraph, source: usize) -> Vec<usize> {
    let mut level = vec![usize::MAX; g.n];
    let mut queue = std::collections::VecDeque::new();
    level[source] = 0;
    queue.push_back(source);
    while let Some(v) = queue.pop_front() {
        for (u, _) in g.neighbors(v) {
            if level[u] == usize::MAX {
                level[u] = level[v] + 1;
                queue.push_back(u);
            }
        }
    }
    level
}

/// Runs one single-source Dijkstra per entry of `sources`, in parallel
/// with per-thread scratch, invoking `f(source_index, distance_row)` for
/// each. `f` runs concurrently for different indices; each index is seen
/// exactly once.
pub fn for_each_source<F>(g: &CsrGraph, sources: &[usize], f: F)
where
    F: Fn(usize, &[f64]) + Sync,
{
    let n_src = sources.len();
    if n_src == 0 {
        return;
    }
    let nt = par::num_threads().min(n_src);
    if nt <= 1 {
        let mut scratch = SsspScratch::new(g.n);
        for (i, &s) in sources.iter().enumerate() {
            f(i, scratch.run(g, &[s]));
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|sc| {
        for _ in 0..nt {
            sc.spawn(|| {
                let mut scratch = SsspScratch::new(g.n);
                loop {
                    let i = counter.fetch_add(1, Ordering::Relaxed);
                    if i >= n_src {
                        break;
                    }
                    f(i, scratch.run(g, &[sources[i]]));
                }
            });
        }
    });
}

/// Materializes the `|sources| × n` distance matrix (row `i` = distances
/// from `sources[i]`).
pub fn distance_matrix(g: &CsrGraph, sources: &[usize]) -> Mat {
    let n = g.n;
    let mut out = Mat::zeros(sources.len(), n);
    {
        let cells = par::as_send_cells(&mut out.data);
        for_each_source(g, sources, |i, d| {
            // SAFETY: each source index is delivered exactly once, and
            // rows are disjoint slices of the output buffer.
            let row =
                unsafe { std::slice::from_raw_parts_mut(cells.get(i * n) as *mut f64, n) };
            row.copy_from_slice(d);
        });
    }
    out
}

/// Per-source distance rows as owned vectors (drop-in for the old
/// `par_map(ns, |i| dijkstra(g, src[i]))` call sites).
pub fn rows(g: &CsrGraph, sources: &[usize]) -> Vec<Vec<f64>> {
    let mut out: Vec<Vec<f64>> = (0..sources.len()).map(|_| Vec::new()).collect();
    {
        let cells = par::as_send_cells(&mut out);
        for_each_source(g, sources, |i, d| {
            // SAFETY: index i is delivered exactly once.
            unsafe { *cells.get(i) = d.to_vec() };
        });
    }
    out
}

/// Multi-source Voronoi decomposition: for every vertex, the distance to
/// the nearest source and that source's index into `sources`
/// (`u32::MAX` = unreachable from every source).
pub fn nearest_sources(g: &CsrGraph, sources: &[usize]) -> (Vec<f64>, Vec<u32>) {
    assert!(sources.len() < u32::MAX as usize);
    let mut assign = vec![u32::MAX; g.n];
    let mut scratch = SsspScratch::new(g.n);
    scratch.run_with_assignment(g, sources, &mut assign);
    (scratch.into_dist(), assign)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid(w: usize, h: usize) -> CsrGraph {
        let mut e = Vec::new();
        for r in 0..h {
            for c in 0..w {
                let v = r * w + c;
                if c + 1 < w {
                    e.push((v, v + 1, 1.0));
                }
                if r + 1 < h {
                    e.push((v, v + w, 1.0));
                }
            }
        }
        CsrGraph::from_edges(w * h, &e)
    }

    #[test]
    fn scratch_matches_dijkstra_across_reuses() {
        let g = grid(7, 5);
        let mut scratch = SsspScratch::new(g.n);
        for s in [0usize, 17, 34, 0, 5] {
            let fast = scratch.run(&g, &[s]).to_vec();
            assert_eq!(fast, dijkstra(&g, s), "source {s}");
        }
    }

    #[test]
    fn lazy_reset_handles_disconnected() {
        // Run on the big component, then from the isolated pair: stale
        // entries from run 1 must not leak into run 2.
        let g = CsrGraph::from_edges(5, &[(0, 1, 1.0), (1, 2, 2.0), (3, 4, 0.5)]);
        let mut scratch = SsspScratch::new(g.n);
        let d1 = scratch.run(&g, &[0]).to_vec();
        assert_eq!(d1[..3], [0.0, 1.0, 3.0]);
        assert!(d1[3].is_infinite() && d1[4].is_infinite());
        let d2 = scratch.run(&g, &[3]).to_vec();
        assert!(d2[0].is_infinite() && d2[2].is_infinite());
        assert_eq!(d2[3], 0.0);
        assert_eq!(d2[4], 0.5);
    }

    #[test]
    fn distance_matrix_matches_per_source() {
        let g = grid(6, 6);
        let sources: Vec<usize> = (0..g.n).step_by(5).collect();
        let m = distance_matrix(&g, &sources);
        assert_eq!((m.rows, m.cols), (sources.len(), g.n));
        for (i, &s) in sources.iter().enumerate() {
            assert_eq!(m.row(i), &dijkstra(&g, s)[..], "source {s}");
        }
    }

    #[test]
    fn rows_matches_distance_matrix() {
        let g = grid(4, 7);
        let sources = vec![3, 11, 26, 0];
        let rs = rows(&g, &sources);
        let m = distance_matrix(&g, &sources);
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(&r[..], m.row(i));
        }
    }

    #[test]
    fn empty_sources_noop() {
        let g = grid(3, 3);
        let m = distance_matrix(&g, &[]);
        assert_eq!((m.rows, m.cols), (0, g.n));
        let mut scratch = SsspScratch::new(g.n);
        let d = scratch.run(&g, &[]);
        assert!(d.iter().all(|x| x.is_infinite()));
    }

    #[test]
    fn multi_source_matches_manhattan_oracle() {
        // 9×4 grid with sources at opposite corners (0 and 35): the
        // nearest-source distance is the min of the two Manhattan terms.
        let g = grid(9, 4);
        let mut scratch = SsspScratch::new(g.n);
        let fast = scratch.run(&g, &[0, 35]).to_vec();
        for r in 0..4usize {
            for c in 0..9usize {
                let want = (r + c).min((3 - r) + (8 - c)) as f64;
                assert_eq!(fast[r * 9 + c], want, "vertex ({r},{c})");
            }
        }
    }

    #[test]
    fn nearest_sources_voronoi_on_path() {
        // Path 0-1-2-3-4-5 with sources at the ends: vertices 0..2 belong
        // to source 0, vertices 4..5 to source 1 (vertex 3 ties — either
        // label is valid, distance must be exact).
        let g = CsrGraph::from_edges(
            6,
            &(0..5).map(|i| (i, i + 1, 1.0)).collect::<Vec<_>>(),
        );
        let (d, a) = nearest_sources(&g, &[0, 5]);
        assert_eq!(d, vec![0.0, 1.0, 2.0, 2.0, 1.0, 0.0]);
        assert_eq!(&a[..2], &[0, 0]);
        assert_eq!(&a[4..], &[1, 1]);
        assert!(a[2] == 0);
        assert!(a[3] == 0 || a[3] == 1);
    }

    #[test]
    fn nearest_sources_unreachable_sentinel() {
        let g = CsrGraph::from_edges(4, &[(0, 1, 1.0)]);
        let (d, a) = nearest_sources(&g, &[0]);
        assert!(d[2].is_infinite() && d[3].is_infinite());
        assert_eq!(a[2], u32::MAX);
        assert_eq!(a[3], u32::MAX);
        assert_eq!(a[0], 0);
        assert_eq!(a[1], 0);
    }
}
