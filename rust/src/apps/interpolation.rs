//! On-surface field interpolation (paper §3.1).
//!
//! Task: given a mesh with a field `F` (vertex normals or velocities),
//! mask a fraction of vertices (zero their field) and reconstruct the
//! masked values as `F̂_i = Σ_{j unmasked} K(i,j) F_j` — one integrator
//! `apply` over the masked field. Quality = mean cosine similarity between
//! predicted and ground-truth vectors on the masked set.

use crate::graph::{distances, CsrGraph};
use crate::integrators::{FieldIntegrator, Workspace};
use crate::linalg::Mat;
use crate::util::rng::Rng;
use crate::util::stats::mean_cosine_sim_rows;

/// A masked-interpolation problem instance.
pub struct InterpolationTask {
    /// Ground-truth field, N×3.
    pub truth: Mat,
    /// Field with masked rows zeroed, N×3.
    pub masked_field: Mat,
    /// Indices of masked vertices (the prediction targets).
    pub masked: Vec<usize>,
}

impl InterpolationTask {
    /// Masks `mask_fraction` of the vertices uniformly at random
    /// (paper: 0.8 for vertex normals, 0.05 for velocities).
    pub fn new(truth: Mat, mask_fraction: f64, rng: &mut Rng) -> Self {
        let n = truth.rows;
        let k = ((n as f64) * mask_fraction).round() as usize;
        let masked = rng.sample_indices(n, k.min(n));
        let mut masked_field = truth.clone();
        for &v in &masked {
            for x in masked_field.row_mut(v) {
                *x = 0.0;
            }
        }
        InterpolationTask { truth, masked_field, masked }
    }

    /// From per-vertex 3-vectors.
    pub fn from_vectors(vectors: &[[f64; 3]], mask_fraction: f64, rng: &mut Rng) -> Self {
        let n = vectors.len();
        let mut truth = Mat::zeros(n, 3);
        for (r, v) in vectors.iter().enumerate() {
            truth.row_mut(r).copy_from_slice(v);
        }
        Self::new(truth, mask_fraction, rng)
    }

    /// Runs an integrator on the masked field and scores the masked rows.
    /// Returns `(cosine_similarity, prediction)`.
    pub fn evaluate(&self, integrator: &dyn FieldIntegrator) -> (f64, Mat) {
        let pred = integrator.apply(&self.masked_field);
        let cos = self.score(&pred);
        (cos, pred)
    }

    /// Allocation-free variant of [`InterpolationTask::evaluate`] for
    /// repeated evaluation loops: the prediction lands in the caller-held
    /// `pred` (`N × 3`) and scratch comes from `ws`.
    pub fn evaluate_into(
        &self,
        integrator: &dyn FieldIntegrator,
        pred: &mut Mat,
        ws: &mut Workspace,
    ) -> f64 {
        integrator.apply_into(&self.masked_field, pred, ws);
        self.score(pred)
    }

    /// Nearest-unmasked baseline: every masked vertex copies the field of
    /// its graph-nearest unmasked vertex — one multi-source Voronoi sweep
    /// through [`distances::nearest_sources`] instead of per-vertex
    /// searches. The floor any kernel integrator has to beat.
    pub fn nearest_unmasked_prediction(&self, g: &CsrGraph) -> Mat {
        let n = self.truth.rows;
        assert_eq!(g.n, n);
        let mut is_masked = vec![false; n];
        for &v in &self.masked {
            is_masked[v] = true;
        }
        let unmasked: Vec<usize> =
            (0..n).filter(|&v| !is_masked[v]).collect();
        let mut pred = self.masked_field.clone();
        if unmasked.is_empty() {
            return pred;
        }
        let (_dist, assign) = distances::nearest_sources(g, &unmasked);
        for &v in &self.masked {
            let a = assign[v];
            if a != u32::MAX {
                let src = unmasked[a as usize];
                pred.row_mut(v).copy_from_slice(self.masked_field.row(src));
            }
        }
        pred
    }

    /// Cosine similarity over masked rows only.
    pub fn score(&self, pred: &Mat) -> f64 {
        let d = self.truth.cols;
        let mut a = Vec::with_capacity(self.masked.len() * d);
        let mut b = Vec::with_capacity(self.masked.len() * d);
        for &v in &self.masked {
            a.extend_from_slice(pred.row(v));
            b.extend_from_slice(self.truth.row(v));
        }
        mean_cosine_sim_rows(&a, &b, d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrators::bf::BruteForceSp;
    use crate::integrators::KernelFn;
    use crate::mesh::icosphere;

    #[test]
    fn mask_counts() {
        let mut rng = Rng::new(1);
        let t = InterpolationTask::new(Mat::zeros(100, 3), 0.8, &mut rng);
        assert_eq!(t.masked.len(), 80);
        // masked rows are zero
        for &v in &t.masked {
            assert!(t.masked_field.row(v).iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn bf_interpolation_recovers_smooth_normals() {
        // Sphere normals are smooth; BF kernel interpolation from 20% of
        // the vertices should align well with ground truth.
        let mesh = icosphere(2);
        let g = mesh.to_graph();
        let normals = mesh.vertex_normals();
        let mut rng = Rng::new(2);
        let task = InterpolationTask::from_vectors(&normals, 0.8, &mut rng);
        let bf = BruteForceSp::new(&g, &KernelFn::ExpNeg(4.0));
        let (cos, _) = task.evaluate(&bf);
        assert!(cos > 0.9, "cosine similarity {cos}");
    }

    #[test]
    fn nearest_unmasked_baseline_reasonable_on_sphere() {
        // Copying the nearest unmasked normal on a sphere should align
        // far better than chance (smooth field, local copies).
        let mesh = icosphere(2);
        let g = mesh.to_graph();
        let normals = mesh.vertex_normals();
        let mut rng = Rng::new(7);
        let task = InterpolationTask::from_vectors(&normals, 0.5, &mut rng);
        let pred = task.nearest_unmasked_prediction(&g);
        let cos = task.score(&pred);
        assert!(cos > 0.7, "nearest-unmasked cosine {cos}");
        // Unmasked rows must be untouched.
        let masked: std::collections::HashSet<usize> =
            task.masked.iter().copied().collect();
        for v in 0..g.n {
            if !masked.contains(&v) {
                assert_eq!(pred.row(v), task.masked_field.row(v));
            }
        }
    }

    #[test]
    fn score_of_truth_is_one() {
        let mesh = icosphere(1);
        let normals = mesh.vertex_normals();
        let mut rng = Rng::new(3);
        let task = InterpolationTask::from_vectors(&normals, 0.5, &mut rng);
        assert!((task.score(&task.truth) - 1.0).abs() < 1e-12);
    }
}
