//! Applications built on the integrators — the paper's §3 experiments.
//!
//! * [`interpolation`] — masked vertex-normal / velocity prediction
//!   (§3.1, Figs. 4/5/9/10/11).
//! * [`attention`] — RFD-masked performer attention (§3.3, the
//!   topological-transformer forward path).

pub mod attention;
pub mod interpolation;
