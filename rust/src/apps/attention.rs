//! Topologically-masked performer attention (paper §3.3, "Topological
//! Transformers"): the Point Cloud Transformer attention matrix is
//! Hadamard-masked by a distance kernel over the 3-D points; with the
//! mask given as RFD's low-rank `M ≈ A Bᵀ`, masked attention runs in
//! sub-quadratic time without materializing either matrix
//! (Choromanski et al. 2022, §3.4):
//!
//! `(M ⊙ Q′K′ᵀ) V = Σ_j diag(A_{:,j}) · Q′ · (K′ᵀ · diag(B_{:,j}) · V)`
//!
//! Cost: `O(N · r · d_v)` per mask feature — linear in N.

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// FAVOR+ positive random features for the softmax kernel:
/// `φ(x) = exp(ωᵀx − ‖x‖²/2) / √r`, giving
/// `E[φ(q)ᵀφ(k)] = exp(qᵀk)`.
pub fn performer_features(x: &Mat, proj: &Mat) -> Mat {
    let (n, _dq) = (x.rows, x.cols);
    let r = proj.rows;
    let mut out = Mat::zeros(n, r);
    for i in 0..n {
        let xi = x.row(i);
        let sq: f64 = xi.iter().map(|v| v * v).sum::<f64>() / 2.0;
        for j in 0..r {
            let dot: f64 = proj.row(j).iter().zip(xi).map(|(a, b)| a * b).sum();
            out[(i, j)] = (dot - sq).exp() / (r as f64).sqrt();
        }
    }
    out
}

/// Gaussian projection matrix for FAVOR+.
pub fn gaussian_projection(r: usize, d: usize, rng: &mut Rng) -> Mat {
    Mat::from_vec(r, d, (0..r * d).map(|_| rng.gaussian()).collect())
}

/// Masked performer attention:
/// `out = D⁻¹ (M ⊙ Q′K′ᵀ) V` with `M = mask_a · mask_bᵀ` (N×2m factors
/// from RFDiffusion) and `Q′, K′` the positive feature maps. `D` is the
/// row-normalizer computed with the same masked product against **1**.
pub fn masked_performer_attention(
    qp: &Mat,
    kp: &Mat,
    v: &Mat,
    mask_a: &Mat,
    mask_b: &Mat,
) -> Mat {
    let n = qp.rows;
    let dv = v.cols;
    assert_eq!(kp.rows, n);
    assert_eq!(mask_a.rows, n);
    assert_eq!(mask_b.rows, n);
    let mfeat = mask_a.cols;
    let mut num = Mat::zeros(n, dv);
    let mut den = vec![0.0; n];
    // Augment V with a ones column to share the two passes. One scratch
    // matrix reused (and re-zeroed) across mask features — the per-feature
    // allocation was the hot-loop's only allocator traffic.
    let mut vj = Mat::zeros(n, dv + 1);
    for j in 0..mfeat {
        // Vj = diag(B[:,j]) [V | 1]
        vj.data.fill(0.0);
        for i in 0..n {
            let b = mask_b[(i, j)];
            if b == 0.0 {
                continue;
            }
            let row = &mut vj.row_mut(i);
            for (dst, &src) in row[..dv].iter_mut().zip(v.row(i)) {
                *dst = b * src;
            }
            row[dv] = b;
        }
        // Sj = K′ᵀ Vj  (r × (dv+1)),  Yj = Q′ Sj  (n × (dv+1))
        let sj = kp.t_matmul(&vj);
        let yj = qp.matmul(&sj);
        for i in 0..n {
            let a = mask_a[(i, j)];
            if a == 0.0 {
                continue;
            }
            let yrow = yj.row(i);
            let nrow = num.row_mut(i);
            for (dst, &src) in nrow.iter_mut().zip(&yrow[..dv]) {
                *dst += a * src;
            }
            den[i] += a * yrow[dv];
        }
    }
    for i in 0..n {
        let d = den[i];
        let scale = if d.abs() > 1e-12 { 1.0 / d } else { 0.0 };
        for x in num.row_mut(i) {
            *x *= scale;
        }
    }
    num
}

/// Exact masked softmax-kernel attention (O(N²) oracle for tests/benches):
/// `out_i = Σ_j M_ij exp(q_iᵀk_j) v_j / Σ_j M_ij exp(q_iᵀk_j)`.
pub fn exact_masked_attention(q: &Mat, k: &Mat, v: &Mat, mask: &Mat) -> Mat {
    let n = q.rows;
    let dv = v.cols;
    let mut out = Mat::zeros(n, dv);
    for i in 0..n {
        let qi = q.row(i);
        let mut den = 0.0;
        let mut acc = vec![0.0; dv];
        for j in 0..n {
            let dot: f64 = qi.iter().zip(k.row(j)).map(|(a, b)| a * b).sum();
            let w = mask[(i, j)] * dot.exp();
            den += w;
            for (a, &x) in acc.iter_mut().zip(v.row(j)) {
                *a += w * x;
            }
        }
        let scale = if den.abs() > 1e-12 { 1.0 / den } else { 0.0 };
        for (o, a) in out.row_mut(i).iter_mut().zip(acc) {
            *o = a * scale;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_inputs(n: usize, dq: usize, dv: usize, seed: u64) -> (Mat, Mat, Mat, Rng) {
        let mut rng = Rng::new(seed);
        let scale = 0.4; // keep exp() well-conditioned for the RF estimate
        let q = Mat::from_vec(n, dq, (0..n * dq).map(|_| scale * rng.gaussian()).collect());
        let k = Mat::from_vec(n, dq, (0..n * dq).map(|_| scale * rng.gaussian()).collect());
        let v = Mat::from_vec(n, dv, (0..n * dv).map(|_| rng.gaussian()).collect());
        (q, k, v, rng)
    }

    #[test]
    fn favor_features_approximate_softmax_kernel() {
        let (q, k, _, mut rng) = small_inputs(20, 4, 2, 1);
        let proj = gaussian_projection(4096, 4, &mut rng);
        let qp = performer_features(&q, &proj);
        let kp = performer_features(&k, &proj);
        for i in 0..5 {
            for j in 0..5 {
                let approx: f64 =
                    qp.row(i).iter().zip(kp.row(j)).map(|(a, b)| a * b).sum();
                let exact: f64 = q
                    .row(i)
                    .iter()
                    .zip(k.row(j))
                    .map(|(a, b)| a * b)
                    .sum::<f64>()
                    .exp();
                assert!(
                    (approx - exact).abs() / exact < 0.2,
                    "RF softmax estimate off: {approx} vs {exact}"
                );
            }
        }
    }

    #[test]
    fn masked_attention_matches_exact_with_rank1_mask() {
        // With an all-ones mask (rank 1: a = b = 1) the masked performer
        // must equal unmasked performer attention = exact attention with
        // exp kernel replaced by the RF estimate. Use exact features by
        // comparing performer-vs-performer: build the dense mask from the
        // same factors, and the dense attention from the same φ maps.
        let n = 16;
        let (q, k, v, mut rng) = small_inputs(n, 3, 2, 2);
        let proj = gaussian_projection(64, 3, &mut rng);
        let qp = performer_features(&q, &proj);
        let kp = performer_features(&k, &proj);
        // Random positive rank-3 mask.
        let a = Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.uniform() + 0.1).collect());
        let b = Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.uniform() + 0.1).collect());
        let mask = a.matmul_nt(&b);
        let fast = masked_performer_attention(&qp, &kp, &v, &a, &b);
        // Dense oracle using the φ-kernel (not exp): K̂_ij = φqᵢᵀφkⱼ.
        let khat = qp.matmul_nt(&kp);
        let mut out = Mat::zeros(n, v.cols);
        for i in 0..n {
            let mut den = 0.0;
            let mut acc = vec![0.0; v.cols];
            for j in 0..n {
                let w = mask[(i, j)] * khat[(i, j)];
                den += w;
                for (x, &vv) in acc.iter_mut().zip(v.row(j)) {
                    *x += w * vv;
                }
            }
            for (o, x) in out.row_mut(i).iter_mut().zip(acc) {
                *o = x / den;
            }
        }
        let e = crate::util::stats::rel_err(&fast.data, &out.data);
        assert!(e < 1e-10, "factored vs dense masked attention: {e}");
    }

    #[test]
    fn approximates_exact_masked_attention_end_to_end() {
        let n = 24;
        let (q, k, v, mut rng) = small_inputs(n, 3, 2, 3);
        let proj = gaussian_projection(2048, 3, &mut rng);
        let qp = performer_features(&q, &proj);
        let kp = performer_features(&k, &proj);
        let a = Mat::from_vec(n, 2, (0..n * 2).map(|_| rng.uniform() + 0.2).collect());
        let b = Mat::from_vec(n, 2, (0..n * 2).map(|_| rng.uniform() + 0.2).collect());
        let mask = a.matmul_nt(&b);
        let fast = masked_performer_attention(&qp, &kp, &v, &a, &b);
        let exact = exact_masked_attention(&q, &k, &v, &mask);
        let e = crate::util::stats::rel_err(&fast.data, &exact.data);
        assert!(e < 0.15, "performer masked attention error {e}");
    }
}
