//! Synthetic datasets — the substitutes for the paper's external data
//! (DESIGN.md §substitutions):
//!
//! * [`mesh_zoo`] — a ladder of procedural meshes over a size range
//!   (Thingi10k substitute for Fig. 4's scaling curves).
//! * [`shape_dataset`] — 10 procedural point-cloud classes with noise and
//!   pose jitter (ModelNet10 substitute, Table 4).
//! * [`cubes_dataset`] — deformed-cube classes (Cubes substitute).
//! * [`graph_dataset`] — labeled graph families (TUDataset substitute,
//!   Table 8).

use crate::classify::graph_kernels::LabeledGraph;
use crate::graph::CsrGraph;
use crate::mesh::{grid_mesh, icosphere, supershape, torus, TriMesh};
use crate::pointcloud::PointCloud;
use crate::util::rng::Rng;

/// A named mesh with its vertex count, for the scaling ladders.
pub struct ZooEntry {
    pub name: String,
    pub mesh: TriMesh,
}

/// Procedural mesh ladder: alternating topology families, sizes roughly
/// doubling from `min_verts` until `max_verts`.
pub fn mesh_zoo(min_verts: usize, max_verts: usize) -> Vec<ZooEntry> {
    let mut out = Vec::new();
    let mut target = min_verts.max(16);
    let mut i = 0usize;
    while target <= max_verts {
        let mesh = match i % 4 {
            0 => {
                // Icosphere: V = 10·4^s + 2; pick s for ≥ target.
                let mut s = 0;
                while 10 * 4usize.pow(s) + 2 < target {
                    s += 1;
                }
                icosphere(s as usize)
            }
            1 => {
                let k = ((target as f64).sqrt().ceil() as usize).max(3);
                grid_mesh(k, k)
            }
            2 => {
                let nu = ((target as f64 / 8.0).sqrt().ceil() as usize * 4).max(8);
                let nv = (target / nu).max(4);
                torus(nu, nv, 1.0, 0.35)
            }
            _ => {
                let nu = ((target as f64).sqrt().ceil() as usize).max(8);
                let nv = (target / nu).max(6);
                supershape(nu, nv, 5.0 + (i % 3) as f64, 3.0 + (i % 5) as f64)
            }
        };
        let mut mesh = mesh;
        mesh.normalize_unit_box();
        out.push(ZooEntry { name: format!("zoo-{i}-{}v", mesh.num_verts()), mesh });
        i += 1;
        target = (target as f64 * 1.7) as usize;
    }
    out
}

/// Samples `n` points from a mesh surface (uniform per-face by area).
pub fn sample_mesh_points(mesh: &TriMesh, n: usize, rng: &mut Rng) -> PointCloud {
    // Cumulative face areas.
    let mut cum = Vec::with_capacity(mesh.num_faces());
    let mut total = 0.0;
    for f in &mesh.faces {
        let [a, b, c] = *f;
        let (pa, pb, pc) = (mesh.verts[a], mesh.verts[b], mesh.verts[c]);
        let u = [pb[0] - pa[0], pb[1] - pa[1], pb[2] - pa[2]];
        let v = [pc[0] - pa[0], pc[1] - pa[1], pc[2] - pa[2]];
        let cx = u[1] * v[2] - u[2] * v[1];
        let cy = u[2] * v[0] - u[0] * v[2];
        let cz = u[0] * v[1] - u[1] * v[0];
        total += 0.5 * (cx * cx + cy * cy + cz * cz).sqrt();
        cum.push(total);
    }
    let mut points = Vec::with_capacity(n);
    for _ in 0..n {
        let r = rng.uniform() * total;
        let fi = cum.partition_point(|&x| x < r).min(mesh.num_faces() - 1);
        let [a, b, c] = mesh.faces[fi];
        // Uniform barycentric sample.
        let (mut s, mut t) = (rng.uniform(), rng.uniform());
        if s + t > 1.0 {
            s = 1.0 - s;
            t = 1.0 - t;
        }
        let (pa, pb, pc) = (mesh.verts[a], mesh.verts[b], mesh.verts[c]);
        points.push([
            pa[0] + s * (pb[0] - pa[0]) + t * (pc[0] - pa[0]),
            pa[1] + s * (pb[1] - pa[1]) + t * (pc[1] - pa[1]),
            pa[2] + s * (pb[2] - pa[2]) + t * (pc[2] - pa[2]),
        ]);
    }
    PointCloud::new(points)
}

/// A labeled point-cloud classification dataset.
pub struct ShapeDataset {
    pub clouds: Vec<PointCloud>,
    pub labels: Vec<usize>,
    pub num_classes: usize,
}

/// 10-class procedural shape dataset (ModelNet10 substitute): spheres,
/// tori (two aspect ratios), grids, supershapes with distinct lobe
/// counts — each instance sampled to `points_per_cloud` with Gaussian
/// noise and anisotropic scale jitter.
pub fn shape_dataset(
    per_class: usize,
    points_per_cloud: usize,
    noise: f64,
    seed: u64,
) -> ShapeDataset {
    let mut rng = Rng::new(seed);
    let protos: Vec<TriMesh> = vec![
        icosphere(2),
        torus(24, 12, 1.0, 0.45),
        torus(24, 12, 1.0, 0.15),
        grid_mesh(16, 16),
        supershape(24, 16, 3.0, 3.0),
        supershape(24, 16, 5.0, 2.0),
        supershape(24, 16, 7.0, 4.0),
        supershape(24, 16, 2.0, 6.0),
        torus(32, 8, 1.0, 0.3),
        supershape(24, 16, 9.0, 3.0),
    ];
    build_dataset(&protos, per_class, points_per_cloud, noise, &mut rng)
}

/// Deformed-cube dataset (Cubes substitute): `num_classes` twist/taper
/// parameterizations of a cube surface grid.
pub fn cubes_dataset(
    num_classes: usize,
    per_class: usize,
    points_per_cloud: usize,
    noise: f64,
    seed: u64,
) -> ShapeDataset {
    let mut rng = Rng::new(seed);
    let protos: Vec<TriMesh> = (0..num_classes)
        .map(|c| {
            let mut m = grid_mesh(12, 12);
            // Fold the grid into a cube-ish shell then deform by class-
            // specific twist + taper.
            let twist = 0.15 + 0.25 * (c % 5) as f64;
            let taper = 0.1 + 0.18 * (c / 5) as f64;
            for v in m.verts.iter_mut() {
                let (x, y) = (v[0] - 0.5, v[1] - 0.5);
                let z = (x * x + y * y) * 1.5;
                let ang = twist * z * (1.0 + c as f64 * 0.13);
                let (s, cs) = ang.sin_cos();
                let scale = 1.0 - taper * z;
                *v = [scale * (x * cs - y * s), scale * (x * s + y * cs), z];
            }
            m
        })
        .collect();
    build_dataset(&protos, per_class, points_per_cloud, noise, &mut rng)
}

fn build_dataset(
    protos: &[TriMesh],
    per_class: usize,
    points_per_cloud: usize,
    noise: f64,
    rng: &mut Rng,
) -> ShapeDataset {
    let mut clouds = Vec::new();
    let mut labels = Vec::new();
    for (cls, proto) in protos.iter().enumerate() {
        let mut proto = proto.clone();
        proto.normalize_unit_box();
        for _ in 0..per_class {
            let mut pc = sample_mesh_points(&proto, points_per_cloud, rng);
            // Anisotropic jitter + noise.
            let sx = 1.0 + 0.15 * rng.gaussian();
            let sy = 1.0 + 0.15 * rng.gaussian();
            let sz = 1.0 + 0.15 * rng.gaussian();
            for p in pc.points.iter_mut() {
                p[0] = p[0] * sx + noise * rng.gaussian();
                p[1] = p[1] * sy + noise * rng.gaussian();
                p[2] = p[2] * sz + noise * rng.gaussian();
            }
            pc.normalize_unit_box();
            clouds.push(pc);
            labels.push(cls);
        }
    }
    // Shuffle consistently.
    let perm = rng.permutation(clouds.len());
    let clouds = perm.iter().map(|&i| clouds[i].clone()).collect();
    let labels = perm.iter().map(|&i| labels[i]).collect();
    ShapeDataset { clouds, labels, num_classes: protos.len() }
}

/// Labeled-graph dataset: `num_classes` synthetic families (rings with
/// chords, random trees, grids, community graphs, stars-of-rings, …) with
/// size jitter — the TUDataset substitute for Table 8.
pub fn graph_dataset(per_class: usize, seed: u64) -> (Vec<LabeledGraph>, Vec<usize>, usize) {
    let mut rng = Rng::new(seed);
    let num_classes = 4;
    let mut graphs = Vec::new();
    let mut labels = Vec::new();
    for cls in 0..num_classes {
        for _ in 0..per_class {
            let n = 14 + rng.below(10);
            let g = match cls {
                0 => ring_with_chords(n, 2 + rng.below(3), &mut rng),
                1 => random_tree(n, &mut rng),
                2 => {
                    let k = ((n as f64).sqrt().ceil() as usize).max(3);
                    let gm = grid_mesh(k, k).to_graph();
                    relabel(gm, &mut rng)
                }
                _ => two_communities(n, &mut rng),
            };
            graphs.push(g);
            labels.push(cls);
        }
    }
    (graphs, labels, num_classes)
}

/// Structure-derived node embeddings: (normalized degree, normalized BFS
/// depth from vertex 0, normalized label). These are the "node features
/// as vectors in d-dimensional space" the RFD graph classifier consumes
/// (paper Appendix F) — they must reflect the graph, not an arbitrary
/// layout, for the ε-NN kernel to carry class signal.
fn structural_positions(g: &CsrGraph, labels: &[usize]) -> Vec<[f64; 3]> {
    let n = g.n;
    let max_deg = (0..n).map(|v| g.degree(v)).max().unwrap_or(1).max(1) as f64;
    let depth = crate::graph::bfs_levels(g, 0);
    let max_depth = depth
        .iter()
        .filter(|&&d| d != usize::MAX)
        .max()
        .copied()
        .unwrap_or(1)
        .max(1) as f64;
    let max_label = labels.iter().max().copied().unwrap_or(1).max(1) as f64;
    (0..n)
        .map(|v| {
            let d = if depth[v] == usize::MAX { 1.0 } else { depth[v] as f64 / max_depth };
            [g.degree(v) as f64 / max_deg, d, labels[v] as f64 / max_label]
        })
        .collect()
}

fn ring_with_chords(n: usize, chords: usize, rng: &mut Rng) -> LabeledGraph {
    let mut edges: Vec<(usize, usize, f64)> = (0..n).map(|i| (i, (i + 1) % n, 1.0)).collect();
    for _ in 0..chords {
        let a = rng.below(n);
        let b = rng.below(n);
        if a != b {
            edges.push((a, b, 1.0));
        }
    }
    let graph = CsrGraph::from_edges(n, &edges);
    let labels: Vec<usize> = (0..n).map(|i| i % 2).collect();
    let positions = structural_positions(&graph, &labels);
    LabeledGraph { graph, labels, positions }
}

fn random_tree(n: usize, rng: &mut Rng) -> LabeledGraph {
    let edges: Vec<(usize, usize, f64)> =
        (1..n).map(|i| (i, rng.below(i), 1.0)).collect();
    let graph = CsrGraph::from_edges(n, &edges);
    let labels: Vec<usize> = (0..n).map(|i| i % 3).collect();
    let positions = structural_positions(&graph, &labels);
    LabeledGraph { graph, labels, positions }
}

fn relabel(g: CsrGraph, rng: &mut Rng) -> LabeledGraph {
    let n = g.n;
    let labels: Vec<usize> = (0..n).map(|_| rng.below(3)).collect();
    let positions = structural_positions(&g, &labels);
    LabeledGraph { graph: g, labels, positions }
}

fn two_communities(n: usize, rng: &mut Rng) -> LabeledGraph {
    let half = n / 2;
    let mut edges = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let same = (i < half) == (j < half);
            let p = if same { 0.5 } else { 0.05 };
            if rng.uniform() < p {
                edges.push((i, j, 1.0));
            }
        }
    }
    // Ensure connectivity backbone.
    for i in 1..n {
        edges.push((i, i - 1, 1.0));
    }
    let graph = CsrGraph::from_edges(n, &edges);
    let labels: Vec<usize> = (0..n).map(|i| usize::from(i >= half)).collect();
    let positions = structural_positions(&graph, &labels);
    LabeledGraph { graph, labels, positions }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_sizes_increase() {
        let zoo = mesh_zoo(100, 3000);
        assert!(zoo.len() >= 4);
        for e in &zoo {
            assert!(e.mesh.num_verts() >= 50);
            assert_eq!(e.mesh.to_graph().num_components(), 1, "{}", e.name);
        }
    }

    #[test]
    fn surface_sampling_on_unit_sphere() {
        let mut rng = Rng::new(1);
        let pc = sample_mesh_points(&icosphere(2), 500, &mut rng);
        assert_eq!(pc.len(), 500);
        for p in &pc.points {
            let r = (p[0] * p[0] + p[1] * p[1] + p[2] * p[2]).sqrt();
            assert!((r - 1.0).abs() < 0.05, "sample off-surface r={r}");
        }
    }

    #[test]
    fn shape_dataset_balanced() {
        let ds = shape_dataset(3, 64, 0.01, 2);
        assert_eq!(ds.clouds.len(), 30);
        assert_eq!(ds.num_classes, 10);
        let mut counts = vec![0; 10];
        for &l in &ds.labels {
            counts[l] += 1;
        }
        assert!(counts.iter().all(|&c| c == 3));
    }

    #[test]
    fn cubes_dataset_distinct_classes() {
        let ds = cubes_dataset(6, 2, 64, 0.0, 3);
        assert_eq!(ds.clouds.len(), 12);
        assert_eq!(ds.num_classes, 6);
    }

    #[test]
    fn graph_dataset_families_connected() {
        let (graphs, labels, ncls) = graph_dataset(3, 4);
        assert_eq!(graphs.len(), 12);
        assert_eq!(ncls, 4);
        assert_eq!(labels.iter().filter(|&&l| l == 0).count(), 3);
        for g in &graphs {
            assert!(g.graph.n >= 14);
            assert_eq!(g.labels.len(), g.graph.n);
        }
    }
}
