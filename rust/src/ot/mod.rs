//! Entropic optimal transport on meshes (paper §3.2 + App. D.1).
//!
//! * [`wasserstein_barycenter`] — paper Algorithm 1: iterative Bregman
//!   projections where every kernel application `K·x` goes through a
//!   pluggable Fast Multiplication (FM) closure — brute force, SF, RFD,
//!   or the heat-kernel baseline.
//! * [`sinkhorn_distance`] — entropic 2-Wasserstein between two
//!   distributions with the same FM abstraction.
//! * [`heat`] — Solomon et al. (2015) convolutional-Wasserstein baseline:
//!   the heat kernel `H ≈ (I + (t/s)L)^{-s}` applied by `s` implicit-Euler
//!   steps, each a conjugate-gradient solve against the sparse mesh
//!   Laplacian (Table 5's `Slmn` column).

pub mod heat;

use crate::linalg::Mat;

/// A Fast-Multiplication closure: applies the (implicit) kernel matrix to
/// a stack of column vectors.
pub type FastMul<'a> = dyn Fn(&Mat) -> Mat + Sync + 'a;

/// Barycenter hyper-parameters.
#[derive(Clone, Debug)]
pub struct BarycenterConfig {
    pub max_iter: usize,
    /// Numerical floor for divisions.
    pub floor: f64,
    /// Early-exit tolerance on the barycenter change.
    pub tol: f64,
}

impl Default for BarycenterConfig {
    fn default() -> Self {
        BarycenterConfig { max_iter: 60, floor: 1e-300, tol: 1e-9 }
    }
}

/// Paper Algorithm 1 (Fast Computation of Wasserstein Barycenter).
///
/// * `mus` — the k input distributions, each a length-N vector.
/// * `area` — per-vertex area weights `a` (Solomon'15's discretization).
/// * `alpha` — barycentric weights (sums to 1).
/// * `fm` — the kernel action.
///
/// Returns the barycenter distribution μ (length N, sums to 1).
pub fn wasserstein_barycenter(
    mus: &[Vec<f64>],
    area: &[f64],
    alpha: &[f64],
    fm: &FastMul,
    cfg: &BarycenterConfig,
) -> Vec<f64> {
    let k = mus.len();
    assert!(k > 0);
    let n = mus[0].len();
    assert_eq!(area.len(), n);
    assert_eq!(alpha.len(), k);
    let mut v = vec![vec![1.0; n]; k];
    let mut w = vec![vec![1.0; n]; k];
    let mut mu = vec![1.0; n];
    let mut d = vec![vec![1.0; n]; k];

    for _iter in 0..cfg.max_iter {
        let prev = mu.clone();
        mu = vec![1.0; n];
        for i in 0..k {
            // 1. wᵢ ← μᵢ ⊘ FM(a ⊗ vᵢ)
            let av: Vec<f64> = area.iter().zip(&v[i]).map(|(a, x)| a * x).collect();
            let kv = fm(&Mat::col_vec(&av));
            for j in 0..n {
                // Clamp: approximate FMs (RFD) can emit tiny negative
                // kernel values; unguarded division then overflows the
                // Bregman scalings into NaN.
                w[i][j] = (mus[i][j] / kv[(j, 0)].max(cfg.floor)).clamp(0.0, 1e30);
            }
            // 2. dᵢ ← vᵢ ⊗ FM(a ⊗ wᵢ)
            let aw: Vec<f64> = area.iter().zip(&w[i]).map(|(a, x)| a * x).collect();
            let kw = fm(&Mat::col_vec(&aw));
            for j in 0..n {
                d[i][j] = (v[i][j] * kw[(j, 0)]).clamp(cfg.floor, 1e30);
            }
            // 3. μ ← μ ⊗ dᵢ^αᵢ
            for j in 0..n {
                mu[j] *= d[i][j].powf(alpha[i]);
            }
        }
        // 4. vᵢ ← vᵢ ⊗ μ ⊘ dᵢ
        for i in 0..k {
            for j in 0..n {
                v[i][j] = (v[i][j] * mu[j] / d[i][j]).clamp(cfg.floor, 1e30);
            }
        }
        let delta: f64 =
            mu.iter().zip(&prev).map(|(a, b)| (a - b).abs()).sum::<f64>() / n as f64;
        if delta < cfg.tol {
            break;
        }
    }
    // Normalize to a probability vector for comparability.
    let total: f64 = mu.iter().sum();
    if total > 0.0 {
        for x in mu.iter_mut() {
            *x /= total;
        }
    }
    mu
}

/// Entropic Sinkhorn transport between μ and ν under the FM kernel.
/// Returns the final scalings `(u, v)`; the implied plan is
/// `T = diag(u) K diag(v)`.
pub fn sinkhorn_scalings(
    mu: &[f64],
    nu: &[f64],
    fm: &FastMul,
    max_iter: usize,
    floor: f64,
) -> (Vec<f64>, Vec<f64>) {
    let n = mu.len();
    assert_eq!(nu.len(), n);
    let mut u = vec![1.0; n];
    let mut v = vec![1.0; n];
    for _ in 0..max_iter {
        let kv = fm(&Mat::col_vec(&v));
        for j in 0..n {
            u[j] = mu[j] / kv[(j, 0)].max(floor);
        }
        let ku = fm(&Mat::col_vec(&u));
        for j in 0..n {
            v[j] = nu[j] / ku[(j, 0)].max(floor);
        }
    }
    (u, v)
}

/// Sinkhorn marginal-violation diagnostic: ‖diag(u)K v − μ‖₁.
pub fn sinkhorn_marginal_error(mu: &[f64], u: &[f64], v: &[f64], fm: &FastMul) -> f64 {
    let kv = fm(&Mat::col_vec(&v.to_vec()));
    mu.iter()
        .enumerate()
        .map(|(j, m)| (u[j] * kv[(j, 0)] - m).abs())
        .sum()
}

/// Builds the k concentrated input distributions the barycenter
/// experiments use (mass around k distinct center vertices, spread by a
/// few hops of the kernel).
pub fn concentrated_distributions(
    n: usize,
    centers: &[usize],
    fm: &FastMul,
) -> Vec<Vec<f64>> {
    centers
        .iter()
        .map(|&c| {
            let mut x = vec![0.0; n];
            x[c] = 1.0;
            let spread = fm(&Mat::col_vec(&x));
            let mut out: Vec<f64> = (0..n).map(|j| spread[(j, 0)].max(0.0)).collect();
            let s: f64 = out.iter().sum();
            if s > 0.0 {
                for t in out.iter_mut() {
                    *t /= s;
                }
            }
            out
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrators::bf::BruteForceSp;
    use crate::integrators::rfd::RfdConfig;
    use crate::integrators::sf::SfConfig;
    use crate::integrators::{prepare, FieldIntegrator, IntegratorSpec, KernelFn, Scene};
    use crate::mesh::icosphere;
    use crate::pointcloud::PointCloud;

    fn sphere_fm() -> (usize, BruteForceSp, Vec<f64>) {
        let mesh = icosphere(2);
        let g = mesh.to_graph();
        let bf = BruteForceSp::new(&g, &KernelFn::ExpNeg(8.0));
        let areas = mesh.vertex_areas();
        (g.n, bf, areas)
    }

    /// BF + SF prepared on the same sphere scene with the same
    /// shortest-path kernel (fine quantization so SF tracks BF tightly),
    /// plus the RFD diffusion integrator for the approximate-FM leg.
    fn sphere_backends() -> (
        usize,
        Vec<f64>,
        BruteForceSp,
        Box<dyn FieldIntegrator>,
        Box<dyn FieldIntegrator>,
    ) {
        let mesh = icosphere(2);
        let g = mesh.to_graph();
        let lam = 8.0;
        let bf = BruteForceSp::new(&g, &KernelFn::ExpNeg(lam));
        let scene = Scene::new(PointCloud::new(mesh.verts.clone()), Some(g.clone()));
        let sf = prepare(
            &scene,
            &IntegratorSpec::Sf(SfConfig {
                kernel: KernelFn::ExpNeg(lam),
                unit_size: 0.002,
                threshold: 64,
                separator_size: 8,
                seed: 1,
            }),
        )
        .unwrap();
        let rfd = prepare(
            &scene,
            &IntegratorSpec::Rfd(RfdConfig {
                num_features: 64,
                epsilon: 0.3,
                lambda: 0.5,
                seed: 3,
                ..Default::default()
            }),
        )
        .unwrap();
        (g.n, mesh.vertex_areas(), bf, sf, rfd)
    }

    fn l1(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    }

    #[test]
    fn barycenter_fm_parity_bf_vs_sf_vs_rfd() {
        // Algorithm 1 is FM-agnostic: swapping the exact BF closure for
        // the SF closure (same kernel) must land on essentially the same
        // barycenter, and the RFD diffusion closure — an approximate FM
        // whose kernel estimates can go slightly negative — must still
        // produce a valid distribution through the clamp path.
        let (n, area, bf, sf, rfd) = sphere_backends();
        let fm_bf = |x: &Mat| bf.apply(x);
        let fm_sf = |x: &Mat| sf.apply(x);
        let alpha = [1.0 / 3.0; 3];
        let cfg = BarycenterConfig { max_iter: 30, ..Default::default() };
        let mus = concentrated_distributions(n, &[0, n / 3, 2 * n / 3], &fm_bf);
        let mu_bf = wasserstein_barycenter(&mus, &area, &alpha, &fm_bf, &cfg);
        let mu_sf = wasserstein_barycenter(&mus, &area, &alpha, &fm_sf, &cfg);
        assert!((mu_sf.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // SF is an approximation (the module's own accuracy tests allow
        // sizable relative error), so the bound is parity-shaped rather
        // than tight: L1 well inside the distributions' diameter of 2.
        let d = l1(&mu_bf, &mu_sf);
        assert!(d < 0.5, "BF vs SF barycenters diverged: L1 {d}");
        // RFD leg: different kernel class, so no parity bound — the
        // invariant is validity (finite, non-negative, normalized).
        let fm_rfd = |x: &Mat| rfd.apply(x);
        let mus_r = concentrated_distributions(n, &[0, n / 3, 2 * n / 3], &fm_rfd);
        let mu_rfd = wasserstein_barycenter(&mus_r, &area, &alpha, &fm_rfd, &cfg);
        assert!(
            mu_rfd.iter().all(|x| x.is_finite() && *x >= 0.0),
            "RFD barycenter left the simplex"
        );
        assert!((mu_rfd.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn negative_kernel_values_hit_the_clamp_not_nan() {
        // Deterministic stand-in for RFD's negative kernel tails: a BF
        // closure with a small negative band injected. The clamp at the
        // Bregman division (rust/src/ot/mod.rs, `wasserstein_barycenter`
        // step 1) must keep every scaling finite.
        let (n, bf, area) = sphere_fm();
        let fm = |x: &Mat| {
            let mut y = bf.apply(x);
            for r in 0..y.rows.min(4) {
                for c in 0..y.cols {
                    y[(r, c)] -= 1e-3;
                }
            }
            y
        };
        let mus = concentrated_distributions(n, &[1, n / 2], &fm);
        let mu = wasserstein_barycenter(
            &mus,
            &area,
            &[0.5, 0.5],
            &fm,
            &BarycenterConfig { max_iter: 25, ..Default::default() },
        );
        assert!(
            mu.iter().all(|v| v.is_finite() && *v >= 0.0),
            "negative kernel values leaked through the clamp"
        );
        let s: f64 = mu.iter().sum();
        assert!((s - 1.0).abs() < 1e-9, "not a distribution: {s}");
    }

    #[test]
    fn sinkhorn_fm_parity_bf_vs_sf() {
        let (n, _area, bf, sf, _) = sphere_backends();
        let fm_bf = |x: &Mat| bf.apply(x);
        let fm_sf = |x: &Mat| sf.apply(x);
        let mus = concentrated_distributions(n, &[1, n / 2], &fm_bf);
        let (u1, v1) = sinkhorn_scalings(&mus[0], &mus[1], &fm_bf, 200, 1e-300);
        let (u2, v2) = sinkhorn_scalings(&mus[0], &mus[1], &fm_sf, 200, 1e-300);
        // Each backend converges onto its own kernel's marginals…
        assert!(sinkhorn_marginal_error(&mus[0], &u1, &v1, &fm_bf) < 1e-6);
        assert!(sinkhorn_marginal_error(&mus[0], &u2, &v2, &fm_sf) < 1e-6);
        // …and the transport plans act the same: compare
        // `diag(u) K (v ⊙ w)` for a fixed test function w.
        let w: Vec<f64> = (0..n).map(|j| j as f64 / n as f64).collect();
        let act = |u: &[f64], v: &[f64], fm: &FastMul| -> Vec<f64> {
            let vw: Vec<f64> = v.iter().zip(&w).map(|(a, b)| a * b).collect();
            let k = fm(&Mat::col_vec(&vw));
            (0..n).map(|j| u[j] * k[(j, 0)]).collect()
        };
        let t_bf = act(&u1, &v1, &fm_bf);
        let t_sf = act(&u2, &v2, &fm_sf);
        let d = l1(&t_bf, &t_sf);
        assert!(d < 0.3, "BF vs SF transport plans diverged: L1 {d}");
    }

    #[test]
    fn barycenter_is_probability() {
        let (n, bf, area) = sphere_fm();
        let fm = |x: &Mat| bf.apply(x);
        let mus = concentrated_distributions(n, &[0, n / 3, 2 * n / 3], &fm);
        let mu = wasserstein_barycenter(
            &mus,
            &area,
            &[1.0 / 3.0; 3],
            &fm,
            &BarycenterConfig { max_iter: 30, ..Default::default() },
        );
        let total: f64 = mu.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(mu.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn barycenter_of_identical_inputs_is_input_like() {
        // All inputs equal → the barycenter concentrates near the same
        // region (mode match is the meaningful invariant under entropic
        // blur).
        let (n, bf, area) = sphere_fm();
        let fm = |x: &Mat| bf.apply(x);
        let mus = concentrated_distributions(n, &[5, 5, 5], &fm);
        let mu = wasserstein_barycenter(
            &mus,
            &area,
            &[1.0 / 3.0; 3],
            &fm,
            &BarycenterConfig { max_iter: 40, ..Default::default() },
        );
        let mode = mu
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let inp_mode = mus[0]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        // Modes should be graph-close: compare kernel similarity.
        let km = bf.kernel()[(mode, inp_mode)];
        let kd = bf.kernel()[(mode, mode)];
        assert!(km / kd > 0.3, "barycenter drifted: K rel {}", km / kd);
    }

    #[test]
    fn sinkhorn_matches_marginals() {
        let (n, bf, _) = sphere_fm();
        let fm = |x: &Mat| bf.apply(x);
        let mus = concentrated_distributions(n, &[1, n / 2], &fm);
        let (u, v) = sinkhorn_scalings(&mus[0], &mus[1], &fm, 200, 1e-300);
        let err = sinkhorn_marginal_error(&mus[0], &u, &v, &fm);
        assert!(err < 1e-6, "marginal violation {err}");
    }

    #[test]
    fn symmetric_weights_give_symmetric_barycenter() {
        // Barycenter with α = (1,0,0) reproduces (a blurred) μ¹.
        let (n, bf, area) = sphere_fm();
        let fm = |x: &Mat| bf.apply(x);
        let mus = concentrated_distributions(n, &[0, n / 2, n - 1], &fm);
        let mu = wasserstein_barycenter(
            &mus,
            &area,
            &[1.0, 0.0, 0.0],
            &fm,
            &BarycenterConfig { max_iter: 40, ..Default::default() },
        );
        let mode = mu
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let want = mus[0]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let km = bf.kernel()[(mode, want)];
        let kd = bf.kernel()[(mode, mode)];
        assert!(km / kd > 0.3, "α=e₁ barycenter far from μ¹");
    }
}
