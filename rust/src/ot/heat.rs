//! Solomon et al. (2015) convolutional-Wasserstein baseline (`Slmn` in
//! paper Table 5): the geodesic Gaussian is replaced by the heat kernel
//! `H = exp(-t·L)` of the mesh Laplacian, applied via `s` implicit-Euler
//! steps `(I + (t/s)·L) x_{k+1} = x_k`, each solved by conjugate
//! gradients against the sparse Laplacian (no dense materialization).

use crate::graph::CsrGraph;
use crate::linalg::Mat;

/// Heat-kernel applier.
pub struct HeatKernel {
    g: CsrGraph,
    /// Diffusion time `t`.
    pub time: f64,
    /// Number of implicit Euler sub-steps `s`.
    pub substeps: usize,
    /// CG iteration cap / tolerance.
    pub cg_max_iter: usize,
    pub cg_tol: f64,
}

impl HeatKernel {
    pub fn new(g: &CsrGraph, time: f64, substeps: usize) -> Self {
        HeatKernel {
            g: g.clone(),
            time,
            substeps: substeps.max(1),
            cg_max_iter: 200,
            cg_tol: 1e-10,
        }
    }

    /// Applies `H ≈ (I + (t/s)L)^{-s}` column-wise.
    pub fn apply(&self, x: &Mat) -> Mat {
        let n = self.g.n;
        assert_eq!(x.rows, n);
        let dt = self.time / self.substeps as f64;
        let mut cur = x.clone();
        for _ in 0..self.substeps {
            let mut next = Mat::zeros(n, x.cols);
            for c in 0..x.cols {
                let b = cur.col(c);
                let sol = self.cg_solve(&b, dt);
                for (r, v) in sol.into_iter().enumerate() {
                    next[(r, c)] = v;
                }
            }
            cur = next;
        }
        cur
    }

    /// CG solve of `(I + dt·L) y = b`. SPD by construction.
    fn cg_solve(&self, b: &[f64], dt: f64) -> Vec<f64> {
        let n = b.len();
        let apply_a = |v: &[f64]| -> Vec<f64> {
            let lv = self.g.laplacian_matvec_multi(v, 1);
            v.iter().zip(lv).map(|(x, l)| x + dt * l).collect()
        };
        let mut x = b.to_vec(); // warm start at b (≈ solution for small dt)
        let ax = apply_a(&x);
        let mut r: Vec<f64> = b.iter().zip(ax).map(|(bb, a)| bb - a).collect();
        let mut p = r.clone();
        let mut rs: f64 = r.iter().map(|v| v * v).sum();
        let b_norm: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
        for _ in 0..self.cg_max_iter {
            if rs.sqrt() / b_norm < self.cg_tol {
                break;
            }
            let ap = apply_a(&p);
            let pap: f64 = p.iter().zip(&ap).map(|(a, b)| a * b).sum();
            if pap <= 0.0 {
                break;
            }
            let alpha = rs / pap;
            for i in 0..n {
                x[i] += alpha * p[i];
                r[i] -= alpha * ap[i];
            }
            let rs_new: f64 = r.iter().map(|v| v * v).sum();
            let beta = rs_new / rs;
            rs = rs_new;
            for i in 0..n {
                p[i] = r[i] + beta * p[i];
            }
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::icosphere;

    #[test]
    fn heat_preserves_total_mass() {
        // L has constant nullspace → implicit Euler preserves Σx.
        let g = icosphere(2).to_graph();
        let hk = HeatKernel::new(&g, 0.1, 4);
        let mut x = Mat::zeros(g.n, 1);
        x[(3, 0)] = 1.0;
        let y = hk.apply(&x);
        let total: f64 = y.data.iter().sum();
        assert!((total - 1.0).abs() < 1e-7, "mass {total}");
        assert!(y.data.iter().all(|&v| v > -1e-9), "negativity");
    }

    #[test]
    fn heat_smooths_towards_uniform() {
        let g = icosphere(1).to_graph();
        let mut x = Mat::zeros(g.n, 1);
        x[(0, 0)] = 1.0;
        let small = HeatKernel::new(&g, 0.01, 2).apply(&x);
        let large = HeatKernel::new(&g, 10.0, 8).apply(&x);
        let peak_small = small.data.iter().cloned().fold(0.0f64, f64::max);
        let peak_large = large.data.iter().cloned().fold(0.0f64, f64::max);
        assert!(peak_large < peak_small, "{peak_large} !< {peak_small}");
        // Long-time limit ≈ uniform.
        let uniform = 1.0 / g.n as f64;
        for &v in &large.data {
            assert!((v - uniform).abs() < 0.5 * uniform);
        }
    }

    #[test]
    fn identity_at_zero_time() {
        let g = icosphere(1).to_graph();
        let hk = HeatKernel::new(&g, 0.0, 3);
        let mut x = Mat::zeros(g.n, 2);
        x[(1, 0)] = 2.0;
        x[(4, 1)] = -1.0;
        let y = hk.apply(&x);
        for (a, b) in y.data.iter().zip(&x.data) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
