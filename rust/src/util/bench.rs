//! Criterion-lite: a tiny benchmarking harness for `cargo bench`
//! (`harness = false` targets in `benches/`). Runs warmup iterations, then
//! timed iterations until a time budget or iteration cap is reached, and
//! prints `name  time: [min median max]`-style lines plus throughput.

use std::time::Instant;

/// One benchmark group with shared configuration.
pub struct Bench {
    warmup_iters: usize,
    max_iters: usize,
    budget_secs: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 2, max_iters: 30, budget_secs: 3.0 }
    }
}

/// Result summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: f64,
    pub median: f64,
    pub max: f64,
    pub mean: f64,
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn with_budget(mut self, secs: f64) -> Self {
        self.budget_secs = secs;
        self
    }
    pub fn with_max_iters(mut self, n: usize) -> Self {
        self.max_iters = n.max(1);
        self
    }

    /// Benchmarks `f`, which should perform one complete measured operation
    /// per call and return a value (returned values are black-boxed so the
    /// optimizer cannot elide the work).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.max_iters
            && (times.len() < 3 || start.elapsed().as_secs_f64() < self.budget_secs)
        {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let res = BenchResult {
            name: name.to_string(),
            iters: times.len(),
            min: times[0],
            median: times[times.len() / 2],
            max: *times.last().unwrap(),
            mean: times.iter().sum::<f64>() / times.len() as f64,
        };
        println!(
            "{:<52} time: [{} {} {}]  ({} iters)",
            res.name,
            fmt_time(res.min),
            fmt_time(res.median),
            fmt_time(res.max),
            res.iters
        );
        res
    }
}

/// Formats seconds with an adaptive unit.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Optimizer barrier (stable-Rust friendly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bench::new().with_budget(0.05).with_max_iters(5);
        let r = b.run("noop", || 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.min <= r.median && r.median <= r.max);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
