//! Criterion-lite: a tiny benchmarking harness for `cargo bench`
//! (`harness = false` targets in `benches/`). Runs warmup iterations, then
//! timed iterations until a time budget or iteration cap is reached, and
//! prints `name  time: [min median max]`-style lines plus throughput.
//!
//! [`write_json`] serializes collected [`BenchResult`]s to a
//! machine-readable `BENCH_*.json` (per-benchmark median/min/max/mean in
//! nanoseconds plus the iteration count), so CI can track the perf
//! trajectory across PRs. `BENCH_BUDGET_SECS` / `BENCH_MAX_ITERS`
//! environment variables override the budget for smoke runs
//! ([`Bench::with_env_overrides`]).

use std::time::Instant;

/// One benchmark group with shared configuration.
pub struct Bench {
    warmup_iters: usize,
    max_iters: usize,
    budget_secs: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 2, max_iters: 30, budget_secs: 3.0 }
    }
}

/// Result summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: f64,
    pub median: f64,
    pub max: f64,
    pub mean: f64,
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn with_budget(mut self, secs: f64) -> Self {
        self.budget_secs = secs;
        self
    }
    pub fn with_max_iters(mut self, n: usize) -> Self {
        self.max_iters = n.max(1);
        self
    }

    /// Applies `BENCH_BUDGET_SECS` / `BENCH_MAX_ITERS` environment
    /// overrides (CI smoke runs shrink the budget without a code change).
    pub fn with_env_overrides(mut self) -> Self {
        if let Some(v) = env_parse::<f64>("BENCH_BUDGET_SECS") {
            self.budget_secs = v;
        }
        if let Some(v) = env_parse::<usize>("BENCH_MAX_ITERS") {
            self.max_iters = v.max(1);
        }
        self
    }

    /// Benchmarks `f`, which should perform one complete measured operation
    /// per call and return a value (returned values are black-boxed so the
    /// optimizer cannot elide the work).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.max_iters
            && (times.len() < 3 || start.elapsed().as_secs_f64() < self.budget_secs)
        {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let res = BenchResult {
            name: name.to_string(),
            iters: times.len(),
            min: times[0],
            median: times[times.len() / 2],
            max: *times.last().unwrap(),
            mean: times.iter().sum::<f64>() / times.len() as f64,
        };
        println!(
            "{:<52} time: [{} {} {}]  ({} iters)",
            res.name,
            fmt_time(res.min),
            fmt_time(res.median),
            fmt_time(res.max),
            res.iters
        );
        res
    }
}

fn env_parse<T: std::str::FromStr>(name: &str) -> Option<T> {
    std::env::var(name).ok().and_then(|s| s.parse().ok())
}

/// Serializes benchmark results to a machine-readable JSON file:
/// `{"benchmarks": [{"name", "iters", "median_ns", "min_ns", "max_ns",
/// "mean_ns"}, …]}`. The perf trajectory tracker diffs these across PRs.
pub fn write_json(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    use crate::util::json::Json;
    let arr = Json::Arr(
        results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("iters", Json::Num(r.iters as f64)),
                    ("median_ns", Json::Num(r.median * 1e9)),
                    ("min_ns", Json::Num(r.min * 1e9)),
                    ("max_ns", Json::Num(r.max * 1e9)),
                    ("mean_ns", Json::Num(r.mean * 1e9)),
                ])
            })
            .collect(),
    );
    let doc = Json::obj(vec![("benchmarks", arr)]);
    std::fs::write(path, format!("{doc}\n"))
}

/// Formats seconds with an adaptive unit.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Optimizer barrier (stable-Rust friendly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bench::new().with_budget(0.05).with_max_iters(5);
        let r = b.run("noop", || 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.min <= r.median && r.median <= r.max);
    }

    #[test]
    fn json_roundtrips() {
        let b = Bench::new().with_budget(0.02).with_max_iters(3);
        let r1 = b.run("case/a", || 2 + 2);
        let r2 = b.run("case/b", || 3 * 3);
        let path = std::env::temp_dir().join("gfi_bench_test.json");
        let path = path.to_str().unwrap().to_string();
        write_json(&path, &[r1, r2]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(&text).unwrap();
        let arr = doc.get("benchmarks").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "case/a");
        assert!(arr[0].get("median_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert!(arr[1].get("iters").unwrap().as_usize().unwrap() >= 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
