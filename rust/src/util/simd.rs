//! Runtime SIMD dispatch for the crate's explicit `core::arch`
//! microkernels (`linalg/gemm.rs`, `integrators/artifacts.rs`,
//! `integrators/rfd.rs`, `graph/distances.rs`).
//!
//! Three layers pick the kernel, highest priority first:
//!
//! 1. **Process override** — [`set_override`], set by
//!    `EngineConfig::simd` and by the differential test suite
//!    (`tests/simd.rs`) to pin one path per assertion.
//! 2. **`GFI_SIMD` env var** — `off` / `scalar` pin the scalar oracle
//!    path, `native` (or unset) enables runtime feature detection. CI
//!    runs the whole test suite under both settings so the scalar
//!    oracle can never bit-rot.
//! 3. **Feature detection** — AVX2 on x86_64
//!    (`is_x86_feature_detected!`), NEON on aarch64 (baseline), scalar
//!    everywhere else. Detected once, cached.
//!
//! **The oracle contract:** every SIMD kernel in this crate performs the
//! *same* floating-point operations in the *same* order as its scalar
//! oracle — multiplies and adds stay separate (no FMA contraction, which
//! would change rounding), reductions keep the scalar association order,
//! and transcendentals (`exp`, `sin_cos`) stay scalar libm per lane.
//! SIMD and scalar results are therefore **bitwise identical**, which is
//! what `tests/simd.rs` asserts (a stronger bar than the ≤1 ULP
//! acceptance criterion). The cost of that contract is that the SIMD
//! win is bounded: the crate builds with `-C target-cpu=native`, so LLVM
//! already auto-vectorizes the scalar kernels where reassociation is not
//! required — see docs/ARCHITECTURE.md, "SIMD & precision".

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Which dispatch path integrator hot loops take.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Always the scalar oracle kernels.
    Scalar,
    /// Runtime feature detection picks the widest available kernel
    /// (AVX2 / NEON), falling back to scalar.
    Native,
}

/// Process-wide override: 0 = none, 1 = scalar, 2 = native.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Pins (or, with `None`, releases) the process-wide dispatch mode.
/// Takes priority over `GFI_SIMD`. Process-global by nature — concurrent
/// callers that need a pinned mode must serialize (the differential
/// suite holds a lock around every pinned section).
pub fn set_override(mode: Option<SimdMode>) {
    let v = match mode {
        None => 0,
        Some(SimdMode::Scalar) => 1,
        Some(SimdMode::Native) => 2,
    };
    OVERRIDE.store(v, Ordering::Relaxed);
}

/// `GFI_SIMD` parse, cached for the process lifetime: `off`/`scalar`/`0`
/// pin the scalar path; `native`/`on` (and any other value, and unset)
/// mean feature detection.
fn env_mode() -> SimdMode {
    static ENV: OnceLock<SimdMode> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("GFI_SIMD") {
        Ok(v) if v.eq_ignore_ascii_case("off")
            || v.eq_ignore_ascii_case("scalar")
            || v == "0" =>
        {
            SimdMode::Scalar
        }
        _ => SimdMode::Native,
    })
}

/// The effective dispatch mode (override, else env, else native).
pub fn mode() -> SimdMode {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => SimdMode::Scalar,
        2 => SimdMode::Native,
        _ => env_mode(),
    }
}

/// One resolved kernel choice, threaded by value through the hot loops
/// so dispatch costs one atomic load per *call*, never per iteration.
/// Variants only exist on architectures that compile their kernels; the
/// scalar fallback is always compiled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kern {
    /// The scalar oracle path.
    Scalar,
    /// AVX2 f64x4 kernels (x86_64, runtime-detected).
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// NEON f64x2 kernels (aarch64 baseline).
    #[cfg(target_arch = "aarch64")]
    Neon,
}

/// Widest kernel the CPU supports, detected once.
fn native_kern() -> Kern {
    static K: OnceLock<Kern> = OnceLock::new();
    *K.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return Kern::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        return Kern::Neon;
        #[cfg(not(target_arch = "aarch64"))]
        Kern::Scalar
    })
}

/// Resolves the kernel for one hot-loop call under the current mode.
pub fn kern() -> Kern {
    match mode() {
        SimdMode::Scalar => Kern::Scalar,
        SimdMode::Native => native_kern(),
    }
}

/// Human-readable name of the currently-resolved kernel (benches, docs).
pub fn kernel_name() -> &'static str {
    match kern() {
        Kern::Scalar => "scalar",
        #[cfg(target_arch = "x86_64")]
        Kern::Avx2 => "avx2",
        #[cfg(target_arch = "aarch64")]
        Kern::Neon => "neon",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn override_wins_and_releases() {
        // Serialized against nothing: unit tests in this module are the
        // only in-crate writers; the integration suite has its own lock.
        set_override(Some(SimdMode::Scalar));
        assert_eq!(mode(), SimdMode::Scalar);
        assert_eq!(kern(), Kern::Scalar);
        set_override(Some(SimdMode::Native));
        assert_eq!(mode(), SimdMode::Native);
        set_override(None);
        let _ = mode(); // env-dependent; just must not panic
        let _ = kernel_name();
    }
}
