//! Error metrics and summary statistics shared by the experiment drivers:
//! cosine similarity (Fig. 4), MSE (Tables 2/3/5), relative error (Fig. 7),
//! and latency percentiles for the coordinator metrics.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// `p`-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Mean squared error between two equally-sized vectors.
pub fn mse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>() / a.len() as f64
}

/// Relative L2 error ‖a−b‖/‖b‖ (with an epsilon guard on ‖b‖).
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
    let den: f64 = b.iter().map(|y| y * y).sum::<f64>().sqrt();
    num / den.max(1e-300)
}

/// Cosine similarity between 3-vectors, averaged over rows; rows where
/// either side is (near-)zero are skipped, matching the vertex-normal
/// evaluation protocol (Sec. 3.1).
pub fn mean_cosine_sim_rows(a: &[f64], b: &[f64], dim: usize) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(dim > 0 && a.len() % dim == 0);
    let n = a.len() / dim;
    let mut acc = 0.0;
    let mut cnt = 0usize;
    for r in 0..n {
        let ra = &a[r * dim..(r + 1) * dim];
        let rb = &b[r * dim..(r + 1) * dim];
        let na: f64 = ra.iter().map(|x| x * x).sum::<f64>().sqrt();
        let nb: f64 = rb.iter().map(|x| x * x).sum::<f64>().sqrt();
        if na < 1e-12 || nb < 1e-12 {
            continue;
        }
        let dot: f64 = ra.iter().zip(rb).map(|(x, y)| x * y).sum();
        acc += dot / (na * nb);
        cnt += 1;
    }
    if cnt == 0 {
        0.0
    } else {
        acc / cnt as f64
    }
}

/// Online latency reservoir for coordinator metrics (fixed capacity,
/// uniform replacement).
#[derive(Debug)]
pub struct Reservoir {
    cap: usize,
    seen: usize,
    samples: Vec<f64>,
    rng_state: u64,
}

impl Reservoir {
    pub fn new(cap: usize) -> Self {
        Reservoir { cap, seen: 0, samples: Vec::with_capacity(cap), rng_state: 0x9E3779B97F4A7C15 }
    }
    fn next(&mut self) -> u64 {
        // xorshift64*
        let mut x = self.rng_state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng_state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            let j = (self.next() % self.seen as u64) as usize;
            if j < self.cap {
                self.samples[j] = x;
            }
        }
    }
    pub fn count(&self) -> usize {
        self.seen
    }
    pub fn percentile(&self, p: f64) -> f64 {
        percentile(&self.samples, p)
    }
    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn mse_relerr() {
        let a = [1.0, 2.0];
        let b = [1.0, 4.0];
        assert!((mse(&a, &b) - 2.0).abs() < 1e-12);
        assert!((rel_err(&a, &a) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_rows() {
        // identical rows → 1; orthogonal → 0
        let a = [1.0, 0.0, 0.0, 0.0, 1.0, 0.0];
        let b = [2.0, 0.0, 0.0, 0.0, 3.0, 0.0];
        assert!((mean_cosine_sim_rows(&a, &b, 3) - 1.0).abs() < 1e-12);
        let c = [0.0, 0.0, 1.0, 0.0, 0.0, 1.0];
        assert!(mean_cosine_sim_rows(&a, &c, 3).abs() < 1e-12);
    }

    #[test]
    fn cosine_skips_zero_rows() {
        let a = [0.0, 0.0, 0.0, 1.0, 0.0, 0.0];
        let b = [1.0, 0.0, 0.0, 1.0, 0.0, 0.0];
        assert!((mean_cosine_sim_rows(&a, &b, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reservoir_caps() {
        let mut r = Reservoir::new(10);
        for i in 0..1000 {
            r.push(i as f64);
        }
        assert_eq!(r.count(), 1000);
        assert!(r.samples.len() == 10);
    }
}
