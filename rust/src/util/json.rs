//! Minimal JSON: the coordinator's wire protocol (JSON-lines over TCP) and
//! the config files need a parser + serializer; serde is not available in
//! the offline build, so this module provides a small, strict subset
//! implementation (no comments, no trailing commas; `\uXXXX` escapes
//! including surrogate pairs are supported).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are always `f64` (the protocol never needs exact
/// 64-bit integers beyond 2^53).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Flattens a JSON array of numbers into `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|x| x.as_f64()).collect()
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num_arr(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        write!(f, "{}", *x as i64)
                    } else {
                        write!(f, "{x}")
                    }
                } else {
                    write!(f, "null") // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parses a complete JSON document; trailing whitespace is allowed, any
/// other trailing content is an error.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing content at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err("lone surrogate".into());
                                }
                            } else {
                                hi
                            };
                            s.push(char::from_u32(cp).ok_or("bad codepoint")?);
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // Re-decode UTF-8 from the byte stream.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err("truncated utf8".into());
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "bad utf8".to_string())?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u".into());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4]).map_err(|_| "bad hex")?;
        self.i += 4;
        u32::from_str_radix(s, 16).map_err(|_| "bad hex".into())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}'"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":[1,2.5,-3],"b":"hi\nthere","c":null,"d":true}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn nested() {
        let v = parse(r#"[[1,[2,[3]]],{"k":{"j":[]}}]"#).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn numbers() {
        for (s, x) in [("0", 0.0), ("-0.5", -0.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(parse(s).unwrap().as_f64().unwrap(), x);
        }
    }
}
