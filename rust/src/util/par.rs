//! Rayon-lite: scoped-thread data parallelism over index ranges.
//!
//! The coordinator and the integrator preprocessing paths only need two
//! primitives: a parallel `for` over a range with chunked work stealing by
//! static partitioning, and a parallel map collecting results in order.
//! Both are built on `std::thread::scope`, so no `'static` bounds leak into
//! call sites.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (capped so over-subscription doesn't
/// hurt the benchmarks).
pub fn num_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Runs `f(i)` for every `i` in `0..n`, distributing indices across threads
/// dynamically (atomic counter, chunk granularity `chunk`). `f` must be
/// `Sync` because multiple workers call it concurrently.
pub fn par_for<F>(n: usize, chunk: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let nt = num_threads().min(n.max(1));
    if nt <= 1 || n <= chunk {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..nt {
            s.spawn(|| loop {
                let start = counter.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Parallel map: computes `f(i)` for `i in 0..n` and returns the results in
/// index order.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = as_send_cells(&mut out);
        par_for(n, 1, |i| {
            // SAFETY: each index i is visited exactly once across all
            // workers (dynamic partition of 0..n), so no slot is written
            // twice or concurrently.
            unsafe { *slots.get(i) = Some(f(i)) };
        });
    }
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Splits a mutable slice into disjoint per-index cells writable from
/// multiple threads. Used to parallelize writes where the partition by
/// index is known to be disjoint.
pub struct SendCells<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: the only `&self` accessor is `get`, whose own contract makes
// concurrent callers touch disjoint indices; with `T: Send` each cell
// may then be mutated from whichever thread claimed it.
unsafe impl<T: Send> Sync for SendCells<'_, T> {}
// SAFETY: the wrapper holds only a raw pointer derived from a `T: Send`
// slice (no thread-affine state), so the handle itself may move.
unsafe impl<T: Send> Send for SendCells<'_, T> {}

impl<T> SendCells<'_, T> {
    /// # Safety
    /// Callers must guarantee no two threads access the same index.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }
}

/// Wraps a mutable slice for disjoint-index parallel writes.
pub fn as_send_cells<T>(xs: &mut [T]) -> SendCells<'_, T> {
    SendCells { ptr: xs.as_mut_ptr(), len: xs.len(), _marker: std::marker::PhantomData }
}

/// Parallel for over disjoint row chunks of a flat row-major buffer:
/// `f(row_index, row_slice)`.
pub fn par_rows<F>(data: &mut [f64], cols: usize, f: F)
where
    F: Fn(usize, &mut [f64]) + Sync,
{
    assert!(cols > 0 && data.len() % cols == 0);
    let rows = data.len() / cols;
    let cells = as_send_cells(data);
    par_for(rows, 8, |r| {
        // SAFETY: rows are disjoint slices of `data`.
        let row = unsafe { std::slice::from_raw_parts_mut(cells.get(r * cols) as *mut f64, cols) };
        f(r, row);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_covers_all() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        par_for(1000, 7, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_ordered() {
        let v = par_map(257, |i| i * i);
        assert_eq!(v, (0..257).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_rows_disjoint() {
        let mut data = vec![0.0; 12 * 5];
        par_rows(&mut data, 5, |r, row| {
            for (c, x) in row.iter_mut().enumerate() {
                *x = (r * 5 + c) as f64;
            }
        });
        assert_eq!(data, (0..60).map(|i| i as f64).collect::<Vec<_>>());
    }
}
