//! Scoped wall-clock timing used by the repro drivers to report the paper's
//! pre-processing / inference split.

use std::time::Instant;

/// Measures the wall-clock duration of `f`, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Accumulating stopwatch for phase breakdowns.
#[derive(Default, Debug, Clone)]
pub struct Stopwatch {
    total: f64,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn add<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let (out, dt) = timed(f);
        self.total += dt;
        out
    }
    pub fn seconds(&self) -> f64 {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value() {
        let (v, dt) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        let a = sw.add(|| 1);
        let b = sw.add(|| 2);
        assert_eq!(a + b, 3);
        assert!(sw.seconds() >= 0.0);
    }
}
