//! anyhow-lite: the error-handling surface the crate needs (`Result`,
//! `anyhow!`, `bail!`, `Context`) implemented over
//! `Box<dyn std::error::Error>`, so the fully-offline build carries no
//! external error crate. The API is source-compatible with the subset of
//! `anyhow` the codebase uses; swap the import path back if the real
//! crate ever lands in the vendored registry.

use std::fmt;

/// Boxed dynamic error. `?` converts from any `std::error::Error` (io,
/// parse, …) via the std blanket `From` impls.
pub type Error = Box<dyn std::error::Error + Send + Sync + 'static>;

/// `Result` with the boxed error as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Builds an [`Error`] from a message (the `anyhow!` macro body).
pub fn msg(m: String) -> Error {
    m.into()
}

/// Context-attaching extension trait for `Result` and `Option`, matching
/// `anyhow::Context`'s `context` / `with_context` methods.
pub trait Context<T> {
    /// Wraps the error (or `None`) with a static context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wraps the error (or `None`) with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| msg(format!("{ctx}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| msg(ctx.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| msg(f().to_string()))
    }
}

/// Formats a message into an [`Error`] (anyhow-compatible).
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::util::error::msg(format!($($t)*))
    };
}

/// Early-returns `Err(anyhow!(…))` (anyhow-compatible).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

// Re-export the crate-root macros under this module's path so call sites
// can `use crate::util::error::{anyhow, bail, Context, Result};`.
pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("boom {}", 7)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 7");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("writing").unwrap_err();
        assert!(e.to_string().starts_with("writing: "));
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3).with_context(|| "x").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
