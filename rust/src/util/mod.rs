//! In-tree substrates for the fully-offline build.
//!
//! The build image vendors only the `xla` crate's dependency closure, so the
//! usual ecosystem crates (rand, rayon, serde, criterion, clap, rustfft) are
//! unavailable. Everything the library needs from them is implemented here:
//!
//! * [`error`] — anyhow-lite `Result`/`Context`/`anyhow!`/`bail!`.
//! * [`rng`] — xoshiro256++ PRNG, Gaussian sampling, shuffles.
//! * [`par`] — scoped-thread parallel maps (rayon-lite).
//! * [`json`] — minimal JSON parser/serializer for the coordinator protocol.
//! * [`bench`] — a criterion-lite timing harness used by `benches/`.
//! * [`codec`] — little-endian framed binary writer/reader + FNV-1a
//!   hashing for the persistent artifact store.
//! * [`stats`] — summary statistics + error metrics shared by the repro
//!   drivers (cosine similarity, MSE, relative error, percentiles).
//! * [`simd`] — runtime SIMD kernel dispatch (`GFI_SIMD`, feature
//!   detection, process override) for the `core::arch` microkernels.
//! * [`timer`] — scoped wall-clock timing.

pub mod bench;
pub mod codec;
pub mod error;
pub mod json;
pub mod par;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod timer;
