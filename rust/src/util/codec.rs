//! Dependency-free little-endian binary codec for persisted artifacts.
//!
//! The artifact store (`coordinator/store.rs`) serializes
//! `StructureArtifact`s into framed files. This module provides the
//! byte-level substrate: a [`Writer`] that appends fixed-width
//! little-endian scalars and length-prefixed sequences to a growable
//! buffer, a bounds-checked [`Reader`] that decodes them with typed
//! errors (never panics on malformed input), and [`fnv1a`] /
//! [`Fnv64`] — the FNV-1a 64-bit hash used both as the content
//! checksum in artifact frames and as the scene fingerprint.
//!
//! Design rules, enforced here so every call site inherits them:
//!
//! - **Little-endian everywhere**, via `to_le_bytes`/`from_le_bytes`;
//!   files written on one host must decode on any other.
//! - **Lengths are `u64`** on the wire and checked against the number
//!   of bytes actually remaining *before* any allocation, so a corrupt
//!   length field is a clean [`CodecError::Truncated`] rather than an
//!   attempted multi-gigabyte allocation.
//! - **`f64` travels as its IEEE-754 bit pattern** (`to_bits`), so
//!   NaN payloads and signed zeros round-trip bitwise — required for
//!   the repo-wide bitwise-identical-results invariant.

use std::fmt;

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// One-shot FNV-1a 64-bit hash of a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

/// Incremental FNV-1a 64-bit hasher (streaming counterpart of
/// [`fnv1a`]); used to fingerprint scenes without materializing their
/// byte representation.
#[derive(Clone, Debug)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    /// Creates a hasher seeded with the FNV offset basis.
    pub fn new() -> Self {
        Fnv64(FNV_OFFSET)
    }

    /// Feeds raw bytes into the hash.
    pub fn write(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
    }

    /// Feeds one `u64` (as its little-endian bytes).
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// Feeds one `f64` as its IEEE-754 bit pattern.
    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    /// Returns the hash of everything written so far.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Typed decode failure. Every variant is a *soft* condition: callers
/// (the artifact store's validation ladder) treat any `CodecError` as
/// "this file is unusable, recompute" — never as corrupted output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// The buffer ended before the declared data did.
    Truncated {
        /// Bytes the decoder needed to make progress.
        needed: u64,
        /// Bytes actually remaining in the buffer.
        have: u64,
    },
    /// A field held a value that cannot be valid (bad enum tag,
    /// non-UTF-8 string, inconsistent dimensions, …).
    Invalid {
        /// Human-readable description of the violation.
        detail: String,
    },
    /// Decoding finished but bytes were left over — the frame does not
    /// match the declared payload exactly.
    Trailing {
        /// Number of unconsumed bytes.
        extra: u64,
    },
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Truncated { needed, have } => {
                write!(f, "truncated input: needed {needed} bytes, have {have}")
            }
            CodecError::Invalid { detail } => write!(f, "invalid encoding: {detail}"),
            CodecError::Trailing { extra } => {
                write!(f, "trailing garbage: {extra} unconsumed bytes")
            }
        }
    }
}

impl std::error::Error for CodecError {}

/// Convenience constructor for [`CodecError::Invalid`].
pub fn invalid(detail: impl Into<String>) -> CodecError {
    CodecError::Invalid { detail: detail.into() }
}

/// Append-only little-endian encoder over a growable byte buffer.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// Creates an empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Creates a writer with `cap` bytes preallocated.
    pub fn with_capacity(cap: usize) -> Self {
        Writer { buf: Vec::with_capacity(cap) }
    }

    /// Consumes the writer, returning the encoded bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Number of bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been written yet.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Appends raw bytes verbatim (no length prefix).
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Appends one byte.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Appends a `u32`, little-endian.
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Appends a `u64`, little-endian.
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Appends a `usize` as a `u64` (the wire has no `usize`).
    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Appends an `f64` as its IEEE-754 bit pattern, little-endian.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Appends a length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Appends a length-prefixed `u32` sequence.
    pub fn put_u32s(&mut self, vs: &[u32]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_u32(v);
        }
    }

    /// Appends a length-prefixed `u64` sequence.
    pub fn put_u64s(&mut self, vs: &[u64]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_u64(v);
        }
    }

    /// Appends a length-prefixed `usize` sequence (each as `u64`).
    pub fn put_usizes(&mut self, vs: &[usize]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_u64(v as u64);
        }
    }

    /// Appends a length-prefixed `f64` sequence (bit patterns).
    pub fn put_f64s(&mut self, vs: &[f64]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_f64(v);
        }
    }

    /// Appends an `f32` as its IEEE-754 bit pattern, little-endian —
    /// bitwise round-trip for the mixed-precision artifacts, same
    /// rationale as `put_f64`.
    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    /// Appends a length-prefixed `f32` sequence (bit patterns).
    pub fn put_f32s(&mut self, vs: &[f32]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_f32(v);
        }
    }
}

/// Bounds-checked little-endian decoder over a borrowed byte slice.
///
/// Every read validates available length first and returns
/// [`CodecError::Truncated`] on shortfall; sequence reads validate the
/// declared element count against the remaining bytes *before*
/// allocating.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Wraps a byte slice for decoding from its start.
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Current read offset from the start of the buffer.
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Succeeds iff every byte has been consumed; otherwise returns
    /// [`CodecError::Trailing`]. Call at the end of a frame decode.
    pub fn finish(&self) -> Result<(), CodecError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(CodecError::Trailing { extra: self.remaining() as u64 })
        }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.remaining() < n {
            return Err(CodecError::Truncated {
                needed: n as u64,
                have: self.remaining() as u64,
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Reads `n` raw bytes (no length prefix).
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        self.take(n)
    }

    /// Reads one byte.
    pub fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    /// Reads a little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32, CodecError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Reads a little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64, CodecError> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    /// Reads a `u64` and checks it fits in `usize` on this host.
    pub fn usize_(&mut self) -> Result<usize, CodecError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| invalid(format!("value {v} exceeds usize")))
    }

    /// Reads an `f64` from its IEEE-754 bit pattern.
    pub fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Reads a declared-length `u64`, validated so that `len * elem`
    /// bytes are actually present before any allocation happens.
    fn seq_len(&mut self, elem: usize) -> Result<usize, CodecError> {
        let n = self.u64()?;
        let need = n.checked_mul(elem as u64).ok_or_else(|| invalid("length overflow"))?;
        if (self.remaining() as u64) < need {
            return Err(CodecError::Truncated { needed: need, have: self.remaining() as u64 });
        }
        Ok(n as usize)
    }

    /// Reads a length-prefixed UTF-8 string.
    pub fn str_(&mut self) -> Result<String, CodecError> {
        let n = self.seq_len(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| invalid("non-UTF-8 string"))
    }

    /// Reads a length-prefixed `u32` sequence.
    pub fn u32s(&mut self) -> Result<Vec<u32>, CodecError> {
        let n = self.seq_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    /// Reads a length-prefixed `u64` sequence.
    pub fn u64s(&mut self) -> Result<Vec<u64>, CodecError> {
        let n = self.seq_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    /// Reads a length-prefixed `usize` sequence (each a wire `u64`).
    pub fn usizes(&mut self) -> Result<Vec<usize>, CodecError> {
        let n = self.seq_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.usize_()?);
        }
        Ok(out)
    }

    /// Reads a length-prefixed `f64` sequence (bit patterns).
    pub fn f64s(&mut self) -> Result<Vec<f64>, CodecError> {
        let n = self.seq_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    /// Reads an `f32` from its IEEE-754 bit pattern.
    pub fn f32(&mut self) -> Result<f32, CodecError> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// Reads a length-prefixed `f32` sequence (bit patterns).
    pub fn f32s(&mut self) -> Result<Vec<f32>, CodecError> {
        let n = self.seq_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_u32(0xdead_beef);
        w.put_u64(u64::MAX - 3);
        w.put_f64(-0.0);
        w.put_f64(f64::INFINITY);
        w.put_f64(f64::NAN);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.f64().unwrap(), f64::INFINITY);
        assert!(r.f64().unwrap().is_nan());
        r.finish().unwrap();
    }

    #[test]
    fn sequence_roundtrip() {
        let mut w = Writer::new();
        w.put_str("sf_tree|u=0.5");
        w.put_u32s(&[1, 2, 3]);
        w.put_usizes(&[0, 10, usize::MAX]);
        w.put_f64s(&[1.5, -2.25]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.str_().unwrap(), "sf_tree|u=0.5");
        assert_eq!(r.u32s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.usizes().unwrap(), vec![0, 10, usize::MAX]);
        assert_eq!(r.f64s().unwrap(), vec![1.5, -2.25]);
        r.finish().unwrap();
    }

    #[test]
    fn f32_bitwise_roundtrip() {
        let vals = [1.5f32, -0.0, f32::INFINITY, f32::NEG_INFINITY, f32::NAN, f32::MIN_POSITIVE];
        let mut w = Writer::new();
        w.put_f32(0.25);
        w.put_f32s(&vals);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.f32().unwrap(), 0.25);
        let back = r.f32s().unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        r.finish().unwrap();
    }

    #[test]
    fn truncated_is_typed_not_panic() {
        let mut w = Writer::new();
        w.put_u64(42);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes[..5]);
        match r.u64() {
            Err(CodecError::Truncated { needed: 8, have: 5 }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn huge_declared_length_rejected_before_alloc() {
        // A corrupt length field claiming 2^60 elements must fail the
        // remaining-bytes check, not attempt the allocation.
        let mut w = Writer::new();
        w.put_u64(1u64 << 60);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(matches!(r.f64s(), Err(CodecError::Truncated { .. })));
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut w = Writer::new();
        w.put_u8(1);
        w.put_u8(2);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        r.u8().unwrap();
        assert_eq!(r.finish(), Err(CodecError::Trailing { extra: 1 }));
    }

    #[test]
    fn fnv_matches_known_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fnv_streaming_matches_oneshot() {
        let mut h = Fnv64::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), fnv1a(b"foobar"));
        let mut h2 = Fnv64::new();
        h2.write_u64(0x0102_0304_0506_0708);
        assert_eq!(h2.finish(), fnv1a(&[8, 7, 6, 5, 4, 3, 2, 1]));
    }
}
