//! Deterministic pseudo-random number generation (xoshiro256++ seeded by
//! SplitMix64) plus the distribution samplers the paper's algorithms need:
//! uniform, Gaussian (Box–Muller), exponential, truncated Gaussian in an
//! L1-ball (the RFDiffusion `ω` distribution), and Fisher–Yates shuffles.

/// xoshiro256++ generator. Deterministic given the seed; every stochastic
/// component of the library (RFD features, Bartal/FRT trees, datasets,
/// random forests) threads one of these through explicitly so experiments
/// are reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller output.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeds the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derives an independent child generator (for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Exponential with rate 1.
    pub fn exponential(&mut self) -> f64 {
        -(1.0 - self.uniform()).ln()
    }

    /// Standard Gaussian vector of dimension `d`.
    pub fn gaussian_vec(&mut self, d: usize) -> Vec<f64> {
        (0..d).map(|_| self.gaussian()).collect()
    }

    /// Standard Gaussian in `R^d` *truncated to the L1-ball of radius `r`*
    /// via rejection sampling — the ω-distribution used by RFDiffusion
    /// (paper Lemma 2.6). The acceptance rate for d=3, r≈2 is ~0.5 so
    /// rejection is cheap; a hard cap guards pathological radii.
    pub fn gaussian_l1_ball(&mut self, d: usize, r: f64) -> Vec<f64> {
        for _ in 0..100_000 {
            let v = self.gaussian_vec(d);
            if v.iter().map(|x| x.abs()).sum::<f64>() <= r {
                return v;
            }
        }
        // Pathologically small radius: fall back to a uniform point well
        // inside the ball rather than spinning forever.
        let mut v = vec![0.0; d];
        let scale = r / (d as f64 * 2.0);
        for x in v.iter_mut() {
            *x = self.uniform_in(-scale, scale);
        }
        v
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Samples `k` distinct indices from `0..n` (k ≤ n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher–Yates.
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn l1_truncation_respected() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.gaussian_l1_ball(3, 1.5);
            assert!(v.iter().map(|x| x.abs()).sum::<f64>() <= 1.5 + 1e-12);
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(4);
        let mut p = r.permutation(100);
        p.sort_unstable();
        assert_eq!(p, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let mut s = r.sample_indices(50, 20);
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }
}
