//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client.
//!
//! The XLA-backed executor is gated behind the `pjrt` cargo feature (which
//! additionally needs the vendored `xla` crate in `Cargo.toml`). The
//! default offline build keeps the full public API but answers every job
//! with an error, so callers degrade to the pure-Rust integrators.
//!
//! Design:
//! * **Executor thread** — the `xla` crate's handles wrap raw C pointers
//!   without `Send`/`Sync`, so one dedicated thread owns the
//!   `PjRtClient` and the compiled-executable cache; callers submit jobs
//!   over an mpsc channel and block on a reply channel. This also
//!   serializes XLA execution (the CPU client is internally threaded).
//! * **Shape buckets** — artifacts exist for a ladder of `(N, m, d)`
//!   shapes (`manifest.json`); requests are padded to the smallest
//!   fitting bucket. Padding is *exact*: the L2 model takes a row mask
//!   and zeroes padded feature rows before the Gram step.
//! * **Compile-once** — `HloModuleProto::from_text_file` → `compile` the
//!   first time a bucket is touched; subsequent calls reuse the cached
//!   executable (compile cost is off the request path after warmup).

use crate::linalg::Mat;
use crate::util::json::{self, Json};
use crate::util::error::{anyhow, bail, Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Mutex;

/// One artifact bucket from the manifest.
#[derive(Clone, Debug)]
pub struct BucketInfo {
    pub file: String,
    pub n: usize,
    pub m: usize,
    pub d: usize,
}

/// A request executed on the runtime thread.
struct Job {
    bucket: BucketInfo,
    /// Flattened f32 inputs in entry-parameter order, with dims
    /// (empty dims = scalar).
    inputs: Vec<(Vec<f32>, Vec<i64>)>,
    reply: mpsc::Sender<Result<Vec<f32>>>,
}

enum Msg {
    Run(Job),
    Shutdown,
}

/// Handle to the PJRT executor.
pub struct PjrtRuntime {
    tx: Mutex<mpsc::Sender<Msg>>,
    manifest: Vec<BucketInfo>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl PjrtRuntime {
    /// Loads the artifact manifest and spawns the executor thread.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = read_manifest(&dir)?;
        if manifest.is_empty() {
            bail!("no artifacts in {}", dir.display());
        }
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker_dir = dir.clone();
        let worker = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || executor_loop(rx, worker_dir))
            .context("spawning pjrt executor")?;
        Ok(PjrtRuntime { tx: Mutex::new(tx), manifest, worker: Some(worker) })
    }

    pub fn buckets(&self) -> &[BucketInfo] {
        &self.manifest
    }

    /// Smallest bucket with `n_bucket ≥ n` and `m_bucket ≥ m` (and the
    /// fixed field width `d`).
    pub fn pick_bucket(&self, n: usize, m: usize, d: usize) -> Option<BucketInfo> {
        self.manifest
            .iter()
            .filter(|b| b.n >= n && b.m >= m && b.d >= d)
            .min_by_key(|b| (b.n, b.m))
            .cloned()
    }

    /// Executes the RFD integration `exp(Λ(W−δI))x` via the AOT artifact.
    ///
    /// * `points` — N×3 (unit-box normalized).
    /// * `omegas` — m×3, `qscale` — m (q_j/m weights).
    /// * `x` — N×d field (d ≤ bucket d; extra columns are zero-padded).
    ///
    /// Returns the N×d result (bucket padding stripped).
    pub fn rfd_apply(
        &self,
        points: &[[f64; 3]],
        omegas: &[[f64; 3]],
        qscale: &[f64],
        x: &Mat,
        lambda: f64,
    ) -> Result<Mat> {
        let n = points.len();
        let m = omegas.len();
        let d = x.cols;
        assert_eq!(x.rows, n);
        let bucket = self
            .pick_bucket(n, m, d)
            .ok_or_else(|| anyhow!("no bucket fits n={n} m={m} d={d}"))?;
        let (bn, bm, bd) = (bucket.n, bucket.m, bucket.d);
        // Pad inputs to bucket shapes.
        let mut pts = vec![0.0f32; bn * 3];
        for (i, p) in points.iter().enumerate() {
            for k in 0..3 {
                pts[i * 3 + k] = p[k] as f32;
            }
        }
        let mut om = vec![0.0f32; bm * 3];
        for (j, w) in omegas.iter().enumerate() {
            for k in 0..3 {
                om[j * 3 + k] = w[k] as f32;
            }
        }
        // Padded ω rows keep q = 0 so they contribute nothing (including
        // to the δ diagonal correction).
        let mut qs = vec![0.0f32; bm];
        for (j, &q) in qscale.iter().enumerate() {
            // The artifact expects q_j/m_bucket pre-divided; callers pass
            // raw q_j and we fold the *real* m here.
            qs[j] = (q / m as f64) as f32;
        }
        let mut xf = vec![0.0f32; bn * bd];
        for r in 0..n {
            for c in 0..d {
                xf[r * bd + c] = x[(r, c)] as f32;
            }
        }
        let mut mask = vec![0.0f32; bn];
        for mk in mask.iter_mut().take(n) {
            *mk = 1.0;
        }
        let inputs = vec![
            (pts, vec![bn as i64, 3]),
            (om, vec![bm as i64, 3]),
            (qs, vec![bm as i64]),
            (xf, vec![bn as i64, bd as i64]),
            (vec![lambda as f32], vec![]),
            (mask, vec![bn as i64]),
        ];
        let out = self.execute_raw(bucket.clone(), inputs)?;
        if out.len() != bn * bd {
            bail!("unexpected output size {} != {}", out.len(), bn * bd);
        }
        let mut result = Mat::zeros(n, d);
        for r in 0..n {
            for c in 0..d {
                result[(r, c)] = out[r * bd + c] as f64;
            }
        }
        Ok(result)
    }

    /// Low-level execute on a named bucket.
    pub fn execute_raw(
        &self,
        bucket: BucketInfo,
        inputs: Vec<(Vec<f32>, Vec<i64>)>,
    ) -> Result<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        // Poison-recovering lock (repo-wide lock discipline): the mutex
        // only serializes `send` on a Sender, which leaves no partial
        // state mid-call, so a panic in some other holder can't have
        // corrupted anything — propagating the poison would permanently
        // kill the PJRT route for every later request instead.
        self.tx
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .send(Msg::Run(Job { bucket, inputs, reply: reply_tx }))
            .map_err(|_| anyhow!("pjrt executor is gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("pjrt executor dropped reply"))?
    }
}

impl Drop for PjrtRuntime {
    fn drop(&mut self) {
        // Poison-recovering for the same reason as `execute_raw` — and
        // doubly so here: a panicking Drop during unwind would abort.
        let _ = self.tx.lock().unwrap_or_else(|p| p.into_inner()).send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn read_manifest(dir: &Path) -> Result<Vec<BucketInfo>> {
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let doc = json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
    let arts = doc
        .get("artifacts")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
    let mut out = Vec::new();
    for a in arts {
        out.push(BucketInfo {
            file: a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing file"))?
                .to_string(),
            n: a.get("n").and_then(Json::as_usize).unwrap_or(0),
            m: a.get("m").and_then(Json::as_usize).unwrap_or(0),
            d: a.get("d").and_then(Json::as_usize).unwrap_or(0),
        });
    }
    Ok(out)
}

/// Stub executor for builds without the vendored `xla` crate (the default
/// offline configuration): every job is answered with an error so the
/// coordinator's pure-Rust fallback paths keep serving.
#[cfg(not(feature = "pjrt"))]
fn executor_loop(rx: mpsc::Receiver<Msg>, _dir: PathBuf) {
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Run(job) => {
                let _ = job
                    .reply
                    .send(Err(anyhow!("built without the `pjrt` feature / xla crate")));
            }
            Msg::Shutdown => break,
        }
    }
}

/// The executor thread body: owns the client + executable cache.
#[cfg(feature = "pjrt")]
fn executor_loop(rx: mpsc::Receiver<Msg>, dir: PathBuf) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Run(job) => {
                        let _ = job.reply.send(Err(anyhow!("PJRT client init failed: {e:?}")));
                    }
                    Msg::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    while let Ok(msg) = rx.recv() {
        let job = match msg {
            Msg::Run(j) => j,
            Msg::Shutdown => break,
        };
        let result = run_job(&client, &mut cache, &dir, &job);
        let _ = job.reply.send(result);
    }
}

#[cfg(feature = "pjrt")]
fn run_job(
    client: &xla::PjRtClient,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    dir: &Path,
    job: &Job,
) -> Result<Vec<f32>> {
    if !cache.contains_key(&job.bucket.file) {
        let path = dir.join(&job.bucket.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compile: {e:?}"))?;
        cache.insert(job.bucket.file.clone(), exe);
    }
    let exe = cache.get(&job.bucket.file).unwrap();
    let mut literals = Vec::with_capacity(job.inputs.len());
    for (data, dims) in &job.inputs {
        let lit = if dims.is_empty() {
            xla::Literal::scalar(data[0])
        } else {
            xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))?
        };
        literals.push(lit);
    }
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow!("execute: {e:?}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
    // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
    let out = lit.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
    out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<PjrtRuntime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return None;
        }
        Some(PjrtRuntime::new(dir).expect("runtime"))
    }

    #[test]
    fn manifest_and_buckets() {
        let Some(rt) = runtime() else { return };
        assert!(!rt.buckets().is_empty());
        let b = rt.pick_bucket(100, 16, 4).expect("bucket for 100");
        assert!(b.n >= 100 && b.m >= 16);
        assert!(rt.pick_bucket(10_000_000, 16, 4).is_none());
    }

    #[test]
    fn end_to_end_identity_at_lambda_zero() {
        let Some(rt) = runtime() else { return };
        let mut rng = crate::util::rng::Rng::new(1);
        let pc = crate::pointcloud::random_cloud(100, &mut rng);
        let cfg =
            crate::integrators::rfd::RfdConfig { num_features: 16, ..Default::default() };
        let (omegas, qscale) = crate::integrators::rfd::sample_features(&cfg);
        let x = Mat::from_vec(100, 3, (0..300).map(|_| rng.gaussian()).collect());
        let y = rt.rfd_apply(&pc.points, &omegas, &qscale, &x, 0.0).expect("apply");
        for (a, b) in y.data.iter().zip(&x.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    // Mirrors the cache-layer poison test, without needing artifacts:
    // build a runtime by hand around the (feature-selected) executor
    // loop, poison the sender mutex mid-hold, and check `execute_raw`
    // still reaches the executor instead of propagating the poison.
    #[test]
    fn poisoned_sender_mutex_recovers_mid_hold() {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::Builder::new()
            .name("pjrt-executor-test".into())
            .spawn(move || executor_loop(rx, PathBuf::from("artifacts-missing")))
            .expect("spawn");
        let rt = PjrtRuntime { tx: Mutex::new(tx), manifest: Vec::new(), worker: Some(worker) };
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = rt.tx.lock().unwrap_or_else(|p| p.into_inner());
            panic!("boom while holding the pjrt sender mutex");
        }));
        assert!(caught.is_err());
        assert!(rt.tx.lock().is_err(), "mutex should be poisoned for the test");
        // The job must round-trip to the executor: a typed Err (stub
        // build or missing artifact file), never a poison panic. Drop
        // then shuts the worker down through the same poisoned mutex.
        let bucket = BucketInfo { file: "missing.hlo".into(), n: 1, m: 1, d: 1 };
        let out = rt.execute_raw(bucket, Vec::new());
        assert!(out.is_err(), "executor should answer with a typed error");
    }

    #[test]
    fn matches_pure_rust_rfd() {
        let Some(rt) = runtime() else { return };
        let mut rng = crate::util::rng::Rng::new(2);
        let pc = crate::pointcloud::random_cloud(200, &mut rng);
        let cfg = crate::integrators::rfd::RfdConfig {
            num_features: 16,
            epsilon: 0.2,
            lambda: -0.2,
            seed: 7,
            ..Default::default()
        };
        let rust_rfd = crate::integrators::rfd::RfDiffusion::try_new(&pc, cfg.clone()).unwrap();
        let (omegas, qscale) = crate::integrators::rfd::sample_features(&cfg);
        let x = Mat::from_vec(200, 3, (0..600).map(|_| rng.gaussian()).collect());
        use crate::integrators::FieldIntegrator;
        let want = rust_rfd.apply(&x);
        let got = rt
            .rfd_apply(&pc.points, &omegas, &qscale, &x, cfg.lambda)
            .expect("apply");
        let e = crate::util::stats::rel_err(&got.data, &want.data);
        assert!(e < 1e-3, "pjrt vs rust rfd: {e}");
    }
}
