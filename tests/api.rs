//! Public-API contract tests for the unified integrator lifecycle:
//! typed `prepare` error paths, `apply_into` vs `apply` bitwise parity
//! per backend, batched apply, workspace reuse, and the engine-level
//! cache-key guarantees (distinct custom kernels never collide).

use gfi::coordinator::Engine;
use gfi::integrators::rfd::RfdConfig;
use gfi::integrators::sf::SfConfig;
use gfi::integrators::trees::TreeKind;
use gfi::integrators::{
    prepare, FieldIntegrator, GfiError, IntegratorSpec, KernelFn, Scene, Workspace,
};
use gfi::linalg::Mat;
use gfi::util::rng::Rng;

fn rand_field(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_vec(n, d, (0..n * d).map(|_| rng.gaussian()).collect())
}

fn mesh_scene() -> Scene {
    let mut mesh = gfi::mesh::icosphere(1);
    mesh.normalize_unit_box();
    Scene::from_mesh(&mesh)
}

fn all_backend_specs() -> Vec<IntegratorSpec> {
    vec![
        IntegratorSpec::Sf(SfConfig { threshold: 16, ..Default::default() }),
        IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
        IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0)),
        IntegratorSpec::BfDiffusion { epsilon: 0.2, lambda: -0.2 },
        IntegratorSpec::Trees { kind: TreeKind::Bartal, count: 3, lambda: 2.0, seed: 1 },
        IntegratorSpec::AlMohy { lambda: -0.2 },
        IntegratorSpec::Lanczos { lambda: -0.2, krylov_dim: 12 },
        IntegratorSpec::Bader { lambda: -0.2 },
    ]
}

/// `apply` is a thin wrapper over `apply_into`: for every backend the two
/// paths must agree **bitwise**, including on a warm (dirty) workspace.
#[test]
fn apply_into_matches_apply_bitwise_per_backend() {
    let scene = mesh_scene();
    let n = scene.len();
    let field = rand_field(n, 3, 7);
    let mut ws = Workspace::new();
    for spec in &all_backend_specs() {
        let integ: Box<dyn FieldIntegrator> =
            prepare(&scene, spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
        let via_apply = integ.apply(&field);
        let mut out = Mat::zeros(n, 3);
        // Run twice on the same workspace: the second run sees recycled
        // (previously dirty) buffers and must still match exactly.
        integ.apply_into(&field, &mut out, &mut ws);
        integ.apply_into(&field, &mut out, &mut ws);
        assert_eq!(
            via_apply.data, out.data,
            "{spec:?}: apply vs apply_into disagree"
        );
    }
}

/// `apply_batch` must equal per-field `apply_into` positionally.
#[test]
fn apply_batch_matches_individual_applies() {
    let scene = mesh_scene();
    let n = scene.len();
    let fields: Vec<Mat> = (0..3).map(|i| rand_field(n, 2, 30 + i)).collect();
    let mut ws = Workspace::new();
    for spec in [
        IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
        IntegratorSpec::Sf(SfConfig { threshold: 16, ..Default::default() }),
    ] {
        let integ = prepare(&scene, &spec).unwrap();
        let mut outs: Vec<Mat> = fields.iter().map(|f| Mat::zeros(n, f.cols)).collect();
        integ.apply_batch(&fields, &mut outs, &mut ws);
        for (f, o) in fields.iter().zip(&outs) {
            assert_eq!(integ.apply(f).data, o.data, "{spec:?}");
        }
    }
}

/// A warm workspace stops allocating: repeated same-shape applies keep
/// the allocation counter flat.
#[test]
fn workspace_goes_allocation_free_after_warmup() {
    let scene = mesh_scene();
    let n = scene.len();
    let field = rand_field(n, 3, 9);
    let mut out = Mat::zeros(n, 3);
    for spec in [
        IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
        IntegratorSpec::Sf(SfConfig { threshold: 16, ..Default::default() }),
        IntegratorSpec::Trees { kind: TreeKind::Mst, count: 2, lambda: 1.0, seed: 0 },
    ] {
        let integ = prepare(&scene, &spec).unwrap();
        let mut ws = Workspace::new();
        integ.apply_into(&field, &mut out, &mut ws);
        let warm = ws.allocations();
        for _ in 0..3 {
            integ.apply_into(&field, &mut out, &mut ws);
        }
        assert_eq!(ws.allocations(), warm, "{spec:?} allocated scratch after warmup");
    }
}

/// Graph-needing backends on a graph-less cloud report `MissingGraph`.
#[test]
fn graph_backends_fail_cleanly_without_graph() {
    let mut rng = Rng::new(1);
    let scene = Scene::from_points(gfi::pointcloud::random_cloud(25, &mut rng));
    for spec in [
        IntegratorSpec::Sf(SfConfig::default()),
        IntegratorSpec::BfSp(KernelFn::ExpNeg(1.0)),
        IntegratorSpec::Trees { kind: TreeKind::Frt, count: 2, lambda: 1.0, seed: 0 },
        IntegratorSpec::AlMohy { lambda: -0.1 },
        IntegratorSpec::Lanczos { lambda: -0.1, krylov_dim: 8 },
        IntegratorSpec::Bader { lambda: -0.1 },
    ] {
        match prepare(&scene, &spec).err() {
            Some(GfiError::MissingGraph { .. }) => {}
            other => panic!("{spec:?}: expected MissingGraph, got {other:?}"),
        }
    }
}

/// An empty cloud is rejected before any backend code runs.
#[test]
fn empty_cloud_is_rejected() {
    let scene = Scene::from_points(gfi::pointcloud::PointCloud::new(Vec::new()));
    for spec in all_backend_specs() {
        match prepare(&scene, &spec).err() {
            Some(GfiError::EmptyScene) => {}
            other => panic!("{spec:?}: expected EmptyScene, got {other:?}"),
        }
    }
}

/// Engine-level: mismatched field dimensions come back as the typed
/// `FieldShape` error (message names both sizes), not a panic.
#[test]
fn engine_rejects_mismatched_field_dims() {
    let engine = Engine::new(None);
    let id = engine.register_mesh(gfi::mesh::icosphere(1), "s");
    let n = engine.cloud(id).unwrap().scene.len();
    let bad = Mat::zeros(n + 1, 3);
    let err = engine
        .integrate(id, &IntegratorSpec::Rfd(RfdConfig::default()), &bad)
        .unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains(&format!("{}", n + 1)) && msg.contains(&format!("{n}")),
        "unhelpful dim error: {msg}"
    );
}

/// Engine-level: two *distinct* custom kernels on the same cloud must not
/// share a cache entry (the seed keyed every custom kernel as "Custom").
#[test]
fn engine_distinguishes_custom_kernels() {
    let engine = Engine::new(None);
    let id = engine.register_mesh(gfi::mesh::icosphere(1), "s");
    let n = engine.cloud(id).unwrap().scene.len();
    let field = rand_field(n, 1, 4);
    let k_wide = IntegratorSpec::BfSp(KernelFn::custom("wide", |x| 1.0 / (1.0 + x)));
    let k_narrow =
        IntegratorSpec::BfSp(KernelFn::custom("narrow", |x| (-10.0 * x).exp()));
    let (out_wide, _) = engine.integrate(id, &k_wide, &field).unwrap();
    let (out_narrow, info) = engine.integrate(id, &k_narrow, &field).unwrap();
    assert!(!info.cache_hit, "distinct custom kernels shared a cache entry");
    let diff: f64 = out_wide
        .data
        .iter()
        .zip(&out_narrow.data)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(diff > 1e-9, "distinct custom kernels returned identical results");
    // Unlabeled custom kernels are unkeyable and rejected by the engine.
    let opaque = IntegratorSpec::BfSp(KernelFn::custom_opaque(|x| (-x).exp()));
    assert!(engine.integrate(id, &opaque, &field).is_err());
    // Direct prepare still works for opaque kernels (no cache involved).
    let mut mesh = gfi::mesh::icosphere(1);
    mesh.normalize_unit_box();
    let scene = Scene::from_mesh(&mesh);
    let opaque_direct =
        prepare(&scene, &IntegratorSpec::BfSp(KernelFn::custom_opaque(|x| (-x).exp())));
    assert!(opaque_direct.is_ok());
}

/// Engine-level: `integrate_into` reuses a right-sized caller buffer and
/// reshapes a wrong-sized one in place.
#[test]
fn engine_integrate_into_handles_caller_buffers() {
    let engine = Engine::new(None);
    let id = engine.register_mesh(gfi::mesh::icosphere(1), "s");
    let n = engine.cloud(id).unwrap().scene.len();
    let field = rand_field(n, 2, 5);
    let spec = IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() });
    // Wrong-shaped buffer gets reshaped.
    let mut out = Mat::zeros(3, 7);
    engine.integrate_into(id, &spec, &field, &mut out).unwrap();
    assert_eq!((out.rows, out.cols), (n, 2));
    // Right-shaped buffer is reused (no reallocation).
    let ptr = out.data.as_ptr();
    engine.integrate_into(id, &spec, &field, &mut out).unwrap();
    assert_eq!(out.data.as_ptr(), ptr);
    let (want, _) = engine.integrate(id, &spec, &field).unwrap();
    assert_eq!(want.data, out.data);
}
