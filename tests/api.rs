//! Public-API contract tests for the unified integrator lifecycle:
//! typed `prepare` error paths, `apply_into` vs `apply` bitwise parity
//! per backend, batched apply, workspace reuse, the engine-level
//! cache-key guarantees (distinct custom kernels never collide), the
//! two-stage prepare pipeline (kernel sweeps share one structure —
//! share counter = 1 — bitwise-identically to from-scratch prepares;
//! structural hyper-parameter changes never share), the bounded-cache
//! lifecycle (budget holds under churn; evicted entries re-prepare
//! bitwise-identically), and concurrent serving through the TCP
//! front-end.

use gfi::coordinator::{server, Engine, EngineConfig, UpdateOpts};
use gfi::integrators::rfd::RfdConfig;
use gfi::integrators::sf::SfConfig;
use gfi::integrators::trees::TreeKind;
use gfi::integrators::{
    prepare, prepare_structure, FieldIntegrator, GfiError, IntegratorSpec, KernelFn, Precision,
    Scene, StructureArtifact, Workspace,
};
use gfi::linalg::Mat;
use gfi::util::rng::Rng;

fn rand_field(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_vec(n, d, (0..n * d).map(|_| rng.gaussian()).collect())
}

fn mesh_scene() -> Scene {
    let mut mesh = gfi::mesh::icosphere(1);
    mesh.normalize_unit_box();
    Scene::from_mesh(&mesh)
}

fn all_backend_specs() -> Vec<IntegratorSpec> {
    vec![
        IntegratorSpec::Sf(SfConfig { threshold: 16, ..Default::default() }),
        IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
        IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0)),
        IntegratorSpec::BfDiffusion { epsilon: 0.2, lambda: -0.2 },
        IntegratorSpec::Trees { kind: TreeKind::Bartal, count: 3, lambda: 2.0, seed: 1 },
        IntegratorSpec::AlMohy { lambda: -0.2 },
        IntegratorSpec::Lanczos { lambda: -0.2, krylov_dim: 12 },
        IntegratorSpec::Bader { lambda: -0.2 },
    ]
}

/// `apply` is a thin wrapper over `apply_into`: for every backend the two
/// paths must agree **bitwise**, including on a warm (dirty) workspace.
#[test]
fn apply_into_matches_apply_bitwise_per_backend() {
    let scene = mesh_scene();
    let n = scene.len();
    let field = rand_field(n, 3, 7);
    let mut ws = Workspace::new();
    for spec in &all_backend_specs() {
        let integ: Box<dyn FieldIntegrator> =
            prepare(&scene, spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
        let via_apply = integ.apply(&field);
        let mut out = Mat::zeros(n, 3);
        // Run twice on the same workspace: the second run sees recycled
        // (previously dirty) buffers and must still match exactly.
        integ.apply_into(&field, &mut out, &mut ws);
        integ.apply_into(&field, &mut out, &mut ws);
        assert_eq!(
            via_apply.data, out.data,
            "{spec:?}: apply vs apply_into disagree"
        );
    }
}

/// `apply_batch` must equal per-field `apply_into` positionally.
#[test]
fn apply_batch_matches_individual_applies() {
    let scene = mesh_scene();
    let n = scene.len();
    let fields: Vec<Mat> = (0..3).map(|i| rand_field(n, 2, 30 + i)).collect();
    let mut ws = Workspace::new();
    for spec in [
        IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
        IntegratorSpec::Sf(SfConfig { threshold: 16, ..Default::default() }),
    ] {
        let integ = prepare(&scene, &spec).unwrap();
        let mut outs: Vec<Mat> = fields.iter().map(|f| Mat::zeros(n, f.cols)).collect();
        integ.apply_batch(&fields, &mut outs, &mut ws);
        for (f, o) in fields.iter().zip(&outs) {
            assert_eq!(integ.apply(f).data, o.data, "{spec:?}");
        }
    }
}

/// A warm workspace stops allocating: repeated same-shape applies keep
/// the allocation counter flat.
#[test]
fn workspace_goes_allocation_free_after_warmup() {
    let scene = mesh_scene();
    let n = scene.len();
    let field = rand_field(n, 3, 9);
    let mut out = Mat::zeros(n, 3);
    for spec in [
        IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
        IntegratorSpec::Sf(SfConfig { threshold: 16, ..Default::default() }),
        IntegratorSpec::Trees { kind: TreeKind::Mst, count: 2, lambda: 1.0, seed: 0 },
    ] {
        let integ = prepare(&scene, &spec).unwrap();
        let mut ws = Workspace::new();
        integ.apply_into(&field, &mut out, &mut ws);
        let warm = ws.allocations();
        for _ in 0..3 {
            integ.apply_into(&field, &mut out, &mut ws);
        }
        assert_eq!(ws.allocations(), warm, "{spec:?} allocated scratch after warmup");
    }
}

/// Graph-needing backends on a graph-less cloud report `MissingGraph`.
#[test]
fn graph_backends_fail_cleanly_without_graph() {
    let mut rng = Rng::new(1);
    let scene = Scene::from_points(gfi::pointcloud::random_cloud(25, &mut rng));
    for spec in [
        IntegratorSpec::Sf(SfConfig::default()),
        IntegratorSpec::BfSp(KernelFn::ExpNeg(1.0)),
        IntegratorSpec::Trees { kind: TreeKind::Frt, count: 2, lambda: 1.0, seed: 0 },
        IntegratorSpec::AlMohy { lambda: -0.1 },
        IntegratorSpec::Lanczos { lambda: -0.1, krylov_dim: 8 },
        IntegratorSpec::Bader { lambda: -0.1 },
    ] {
        match prepare(&scene, &spec).err() {
            Some(GfiError::MissingGraph { .. }) => {}
            other => panic!("{spec:?}: expected MissingGraph, got {other:?}"),
        }
    }
}

/// An empty cloud is rejected before any backend code runs.
#[test]
fn empty_cloud_is_rejected() {
    let scene = Scene::from_points(gfi::pointcloud::PointCloud::new(Vec::new()));
    for spec in all_backend_specs() {
        match prepare(&scene, &spec).err() {
            Some(GfiError::EmptyScene) => {}
            other => panic!("{spec:?}: expected EmptyScene, got {other:?}"),
        }
    }
}

/// Engine-level: mismatched field dimensions come back as the typed
/// `FieldShape` error (message names both sizes), not a panic.
#[test]
fn engine_rejects_mismatched_field_dims() {
    let engine = Engine::new(None);
    let id = engine.register_mesh(gfi::mesh::icosphere(1), "s");
    let n = engine.cloud(id).unwrap().scene.len();
    let bad = Mat::zeros(n + 1, 3);
    let err = engine
        .integrate(id, &IntegratorSpec::Rfd(RfdConfig::default()), &bad)
        .unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains(&format!("{}", n + 1)) && msg.contains(&format!("{n}")),
        "unhelpful dim error: {msg}"
    );
}

/// Engine-level: two *distinct* custom kernels on the same cloud must not
/// share a cache entry (the seed keyed every custom kernel as "Custom").
#[test]
fn engine_distinguishes_custom_kernels() {
    let engine = Engine::new(None);
    let id = engine.register_mesh(gfi::mesh::icosphere(1), "s");
    let n = engine.cloud(id).unwrap().scene.len();
    let field = rand_field(n, 1, 4);
    let k_wide = IntegratorSpec::BfSp(KernelFn::custom("wide", |x| 1.0 / (1.0 + x)));
    let k_narrow =
        IntegratorSpec::BfSp(KernelFn::custom("narrow", |x| (-10.0 * x).exp()));
    let (out_wide, _) = engine.integrate(id, &k_wide, &field).unwrap();
    let (out_narrow, info) = engine.integrate(id, &k_narrow, &field).unwrap();
    assert!(!info.cache_hit, "distinct custom kernels shared a cache entry");
    let diff: f64 = out_wide
        .data
        .iter()
        .zip(&out_narrow.data)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(diff > 1e-9, "distinct custom kernels returned identical results");
    // Unlabeled custom kernels are unkeyable and rejected by the engine.
    let opaque = IntegratorSpec::BfSp(KernelFn::custom_opaque(|x| (-x).exp()));
    assert!(engine.integrate(id, &opaque, &field).is_err());
    // Direct prepare still works for opaque kernels (no cache involved).
    let mut mesh = gfi::mesh::icosphere(1);
    mesh.normalize_unit_box();
    let scene = Scene::from_mesh(&mesh);
    let opaque_direct =
        prepare(&scene, &IntegratorSpec::BfSp(KernelFn::custom_opaque(|x| (-x).exp())));
    assert!(opaque_direct.is_ok());
}

/// Engine-level: `integrate_into` reuses a right-sized caller buffer and
/// reshapes a wrong-sized one in place.
#[test]
fn engine_integrate_into_handles_caller_buffers() {
    let engine = Engine::new(None);
    let id = engine.register_mesh(gfi::mesh::icosphere(1), "s");
    let n = engine.cloud(id).unwrap().scene.len();
    let field = rand_field(n, 2, 5);
    let spec = IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() });
    // Wrong-shaped buffer gets reshaped.
    let mut out = Mat::zeros(3, 7);
    engine.integrate_into(id, &spec, &field, &mut out).unwrap();
    assert_eq!((out.rows, out.cols), (n, 2));
    // Right-shaped buffer is reused (no reallocation).
    let ptr = out.data.as_ptr();
    engine.integrate_into(id, &spec, &field, &mut out).unwrap();
    assert_eq!(out.data.as_ptr(), ptr);
    let (want, _) = engine.integrate(id, &spec, &field).unwrap();
    assert_eq!(want.data, out.data);
}

/// Every backend reports a resident footprint that at least covers its
/// dominant storage, and the dense backends dominate the low-rank ones —
/// the ordering the cost-aware cache relies on.
#[test]
fn resident_bytes_reflect_backend_storage() {
    let scene = mesh_scene();
    let n = scene.len();
    for spec in all_backend_specs() {
        let integ = prepare(&scene, &spec).unwrap();
        assert!(
            integ.resident_bytes() >= n * 8,
            "{spec:?}: implausibly small resident_bytes {}",
            integ.resident_bytes()
        );
    }
    let dense = prepare(&scene, &IntegratorSpec::BfSp(KernelFn::ExpNeg(1.0))).unwrap();
    let lowrank =
        prepare(&scene, &IntegratorSpec::Rfd(RfdConfig { num_features: 4, ..Default::default() }))
            .unwrap();
    assert!(
        dense.resident_bytes() >= n * n * 8,
        "dense kernel must be charged its n² matrix"
    );
    assert!(
        dense.resident_bytes() > lowrank.resident_bytes(),
        "cost accounting must separate dense ({}) from low-rank ({})",
        dense.resident_bytes(),
        lowrank.resident_bytes()
    );
}

/// Acceptance: with `max_resident_bytes` set, a churn workload over more
/// distinct `(cloud, spec)` pairs than the budget holds keeps reported
/// resident bytes ≤ budget, surfaces evictions in the stats, and
/// re-requesting an evicted spec returns results bitwise-identical to an
/// unbounded engine.
#[test]
fn bounded_engine_holds_budget_and_rebuilds_bitwise_identically() {
    // Probe the per-entry cost so the budget holds exactly ~2 of the 5
    // prepared integrators used below.
    let probe = Engine::new(None);
    let pid = probe.register_mesh(gfi::mesh::icosphere(1), "probe");
    let pn = probe.cloud(pid).unwrap().scene.len();
    let probe_spec = IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() });
    probe.integrate(pid, &probe_spec, &rand_field(pn, 2, 1)).unwrap();
    let budget = probe.resident_bytes() * 5 / 2;

    let bounded = EngineConfig::default()
        .shards(4)
        .max_resident_bytes(budget)
        .build();
    let unbounded = Engine::new(None);
    let bid = bounded.register_mesh(gfi::mesh::icosphere(1), "s");
    let uid = unbounded.register_mesh(gfi::mesh::icosphere(1), "s");
    let n = bounded.cloud(bid).unwrap().scene.len();
    let field = rand_field(n, 2, 2);
    let specs: Vec<IntegratorSpec> = (0..5)
        .map(|seed| {
            IntegratorSpec::Rfd(RfdConfig { num_features: 8, seed, ..Default::default() })
        })
        .collect();

    // Two full churn passes: pass 2 re-requests entries pass 1 evicted.
    let mut rebuilt = 0;
    for pass in 0..2 {
        for spec in &specs {
            let (got, info) = bounded.integrate(bid, spec, &field).unwrap();
            let (want, _) = unbounded.integrate(uid, spec, &field).unwrap();
            assert_eq!(
                want.data, got.data,
                "bounded engine diverged from unbounded on {spec:?}"
            );
            assert!(
                bounded.resident_bytes() <= budget,
                "resident {} exceeds budget {budget}",
                bounded.resident_bytes()
            );
            if pass == 1 && !info.cache_hit {
                rebuilt += 1;
            }
        }
    }
    let stats = bounded.cache_stats();
    assert!(
        stats.integrators.evictions >= 5,
        "5 specs × 2 passes against a 2-entry budget must evict: {stats:?}"
    );
    assert!(rebuilt >= 1, "second pass must transparently re-prepare evicted entries");
    // The unbounded engine kept everything (and reports it).
    assert_eq!(unbounded.cache_stats().integrators.entries, 5);
    assert_eq!(unbounded.cache_stats().integrators.evictions, 0);
}

/// Four concurrent wire clients across mixed backends: every response is
/// well-formed and the per-backend metrics in `stats` sum to the request
/// total.
#[test]
fn concurrent_server_clients_mixed_backends() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::Arc;

    const CLIENTS: usize = 4;
    const REQUESTS: usize = 6;
    let backends: [&str; 4] = ["rfd", "bf_sp", "almohy", "trees_mst"];

    let engine = Arc::new(Engine::new(None));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let eng2 = engine.clone();
    let server_thread = std::thread::spawn(move || {
        server::serve_with(
            eng2,
            "127.0.0.1:0",
            server::ServerConfig { max_connections: CLIENTS + 1 },
            move |a| addr_tx.send(a).unwrap(),
        )
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap();

    let send = |stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str| {
        writeln!(stream, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        gfi::util::json::parse(&resp).unwrap()
    };

    let mut ctl = TcpStream::connect(addr).unwrap();
    let mut ctl_reader = BufReader::new(ctl.try_clone().unwrap());
    let reg = send(
        &mut ctl,
        &mut ctl_reader,
        r#"{"op":"register_mesh","kind":"icosphere","param":1}"#,
    );
    let n = reg.get("n").unwrap().as_usize().unwrap();

    std::thread::scope(|s| {
        let backends = &backends;
        for cid in 0..CLIENTS {
            s.spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut rng = Rng::new(cid as u64 + 1);
                for r in 0..REQUESTS {
                    let backend = backends[(cid + r) % backends.len()];
                    let field: Vec<String> =
                        (0..n).map(|_| format!("{:.5}", rng.gaussian())).collect();
                    let req = format!(
                        r#"{{"op":"integrate","cloud":1,"backend":"{backend}","field":[{}],"d":1,"lambda":{},"m":8,"count":2}}"#,
                        field.join(","),
                        if backend == "almohy" { -0.2 } else { 1.0 },
                    );
                    writeln!(stream, "{req}").unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    let json = gfi::util::json::parse(&resp)
                        .unwrap_or_else(|e| panic!("malformed response {resp:?}: {e}"));
                    assert_eq!(
                        json.get("ok").and_then(|j| j.as_bool()),
                        Some(true),
                        "{json}"
                    );
                    assert_eq!(
                        json.get("result").unwrap().as_arr().unwrap().len(),
                        n,
                        "wrong result length from {backend}"
                    );
                }
            });
        }
    });

    let stats = send(&mut ctl, &mut ctl_reader, r#"{"op":"stats"}"#);
    let by_backend = stats.get("backends").unwrap();
    // spec.name() collapses the tree kinds to "trees".
    let expected = [("rfd", "rfd"), ("bf_sp", "bf_sp"), ("almohy", "almohy"), ("trees_mst", "trees")];
    let mut total = 0;
    for (wire, metric) in expected {
        let count = by_backend
            .get(metric)
            .and_then(|b| b.get("count"))
            .and_then(|c| c.as_usize())
            .unwrap_or_else(|| panic!("no metrics for {wire} (as {metric}): {stats}"));
        assert_eq!(count, CLIENTS * REQUESTS / backends.len(), "{metric}");
        total += count;
    }
    assert_eq!(total, CLIENTS * REQUESTS, "per-backend metrics don't sum to the total");

    send(&mut ctl, &mut ctl_reader, r#"{"op":"shutdown"}"#);
    server_thread.join().unwrap();
}

/// ISSUE 5 acceptance: preparing two specs that differ only in kernel on
/// the same `(cloud, epoch)` performs the structure stage once — the
/// structure cache's share counter (`hits`) is exactly 1 — and every
/// shared-structure prepare is bitwise-identical to a from-scratch
/// `prepare` on the same scene.
#[test]
fn kernel_sweep_shares_structure_once_and_is_bitwise_identical() {
    let engine = Engine::new(None);
    let id = engine.register_mesh(gfi::mesh::icosphere(2), "s");
    let scene = engine.cloud(id).unwrap().scene.clone();
    let n = scene.len();
    let field = rand_field(n, 3, 77);

    // SF: same tree parameters, different kernels.
    let sf_of = |kernel: KernelFn| {
        IntegratorSpec::Sf(SfConfig { kernel, threshold: 64, ..Default::default() })
    };
    let (out_a, info_a) = engine.integrate(id, &sf_of(KernelFn::ExpNeg(1.0)), &field).unwrap();
    assert!(!info_a.cache_hit && !info_a.structure_shared);
    let (out_b, info_b) = engine.integrate(id, &sf_of(KernelFn::ExpNeg(4.0)), &field).unwrap();
    assert!(!info_b.cache_hit, "distinct kernels must not share an integrator entry");
    assert!(info_b.structure_shared, "second kernel must reuse the separator tree");
    let stats = engine.cache_stats();
    assert_eq!(stats.structures.hits, 1, "share counter must be exactly 1: {stats:?}");
    assert_eq!(stats.structures.entries, 1, "one tree serves both kernels: {stats:?}");
    assert_eq!(stats.integrators.entries, 2);
    for (kernel, out) in [(KernelFn::ExpNeg(1.0), &out_a), (KernelFn::ExpNeg(4.0), &out_b)] {
        let fresh = prepare(&scene, &sf_of(kernel)).unwrap();
        assert_eq!(
            out.data,
            fresh.apply(&field).data,
            "shared-structure prepare diverged from from-scratch"
        );
    }

    // BF-sp: one distance matrix serves every kernel, and GW's
    // shortest-path structure is built from the same artifact family.
    let (bf_a, i_a) = engine.integrate(id, &IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0)), &field).unwrap();
    assert!(!i_a.structure_shared);
    let (bf_b, i_b) = engine
        .integrate(id, &IntegratorSpec::BfSp(KernelFn::GaussianSq(1.5)), &field)
        .unwrap();
    assert!(i_b.structure_shared, "BF-sp kernels must share the distance matrix");
    for (kernel, out) in
        [(KernelFn::ExpNeg(2.0), &bf_a), (KernelFn::GaussianSq(1.5), &bf_b)]
    {
        let fresh = prepare(&scene, &IntegratorSpec::BfSp(kernel)).unwrap();
        assert_eq!(out.data, fresh.apply(&field).data);
    }

    // RFD: a Λ/ridge sweep shares the feature structure.
    let rfd_of = |lambda: f64, ridge: f64| {
        IntegratorSpec::Rfd(RfdConfig { num_features: 8, lambda, ridge, ..Default::default() })
    };
    let (rf_a, ri_a) = engine.integrate(id, &rfd_of(-0.1, 1e-8), &field).unwrap();
    assert!(!ri_a.structure_shared);
    let (rf_b, ri_b) = engine.integrate(id, &rfd_of(-0.4, 1e-6), &field).unwrap();
    assert!(ri_b.structure_shared, "Λ/ridge sweep must reuse the RFD features");
    for (spec, out) in [(rfd_of(-0.1, 1e-8), &rf_a), (rfd_of(-0.4, 1e-6), &rf_b)] {
        let fresh = prepare(&scene, &spec).unwrap();
        assert_eq!(out.data, fresh.apply(&field).data);
    }

    // Trees: a λ sweep shares the sampled ensemble.
    let trees_of = |lambda: f64| IntegratorSpec::Trees {
        kind: TreeKind::Bartal,
        count: 3,
        lambda,
        seed: 5,
    };
    let (t_a, ti_a) = engine.integrate(id, &trees_of(1.0), &field).unwrap();
    assert!(!ti_a.structure_shared);
    let (t_b, ti_b) = engine.integrate(id, &trees_of(2.5), &field).unwrap();
    assert!(ti_b.structure_shared, "λ sweep must reuse the sampled trees");
    for (spec, out) in [(trees_of(1.0), &t_a), (trees_of(2.5), &t_b)] {
        let fresh = prepare(&scene, &spec).unwrap();
        assert_eq!(out.data, fresh.apply(&field).data);
    }
}

/// Refreshable backends expose the shared structure they hold — the
/// hook `update_cloud` uses to refresh a tree exactly once even when the
/// structure-store entry was evicted under byte pressure.
#[test]
fn integrators_expose_their_shared_structure() {
    let scene = mesh_scene();
    let sf = prepare(
        &scene,
        &IntegratorSpec::Sf(SfConfig { threshold: 16, ..Default::default() }),
    )
    .unwrap();
    assert_eq!(
        sf.structure_artifact().map(|a| a.kind()),
        Some("sf_tree"),
        "SF must expose its separator tree"
    );
    let rfd = prepare(
        &scene,
        &IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
    )
    .unwrap();
    assert_eq!(rfd.structure_artifact().map(|a| a.kind()), Some("rfd_features"));
    // Backends without an incremental structure path expose nothing.
    let bf = prepare(&scene, &IntegratorSpec::BfSp(KernelFn::ExpNeg(1.0))).unwrap();
    assert!(bf.structure_artifact().is_none());
}

/// Structural-key hygiene (the collision-test mirror of PR 2's
/// `cache_key` fixes): specs differing in *any* structural
/// hyper-parameter must not share a structure — only kernel-stage
/// parameters may collapse onto one artifact.
#[test]
fn structural_hyperparameter_changes_never_share_a_structure() {
    let engine = Engine::new(None);
    let id = engine.register_mesh(gfi::mesh::icosphere(1), "s");
    let n = engine.cloud(id).unwrap().scene.len();
    let field = rand_field(n, 2, 78);

    // SF: each structural variant must build its own tree.
    let variants = [
        SfConfig { threshold: 16, ..Default::default() },
        SfConfig { threshold: 32, ..Default::default() },
        SfConfig { threshold: 16, seed: 9, ..Default::default() },
        SfConfig { threshold: 16, separator_size: 8, ..Default::default() },
        SfConfig { threshold: 16, unit_size: 0.02, ..Default::default() },
    ];
    for cfg in &variants {
        let info = engine
            .integrate(id, &IntegratorSpec::Sf(cfg.clone()), &field)
            .unwrap()
            .1;
        assert!(
            !info.structure_shared,
            "structurally distinct SF spec shared a tree: {cfg:?}"
        );
    }
    let stats = engine.cache_stats();
    assert_eq!(stats.structures.hits, 0, "no structural variant may share: {stats:?}");
    assert_eq!(stats.structures.entries, variants.len());

    // RFD: sigma/epsilon/m/seed are structural — no sharing across them.
    let base = RfdConfig { num_features: 8, ..Default::default() };
    let rfd_variants = [
        base.clone(),
        RfdConfig { sigma: Some(2.0), ..base.clone() },
        RfdConfig { epsilon: 0.2, ..base.clone() },
        RfdConfig { seed: 3, ..base.clone() },
        RfdConfig { num_features: 12, ..base.clone() },
    ];
    for cfg in &rfd_variants {
        let info = engine
            .integrate(id, &IntegratorSpec::Rfd(cfg.clone()), &field)
            .unwrap()
            .1;
        assert!(
            !info.structure_shared,
            "structurally distinct RFD spec shared features: {cfg:?}"
        );
    }
    assert_eq!(engine.cache_stats().structures.hits, 0);
}

/// A frame update followed by a kernel sweep shares one *refreshed*
/// tree: `update_cloud` migrates the structure once, re-derives the
/// cached integrators' kernel stages from it, and post-update prepares
/// of new kernels share the refreshed structure — all bitwise-identical
/// to from-scratch prepares on the updated scene.
#[test]
fn update_cloud_migrates_structure_once_for_kernel_sweeps() {
    let mut mesh = gfi::mesh::icosphere(3); // 642 vertices
    mesh.normalize_unit_box();
    let n = mesh.num_verts();
    let eng = Engine::new(None);
    let id = eng.register_scene(Scene::from_mesh(&mesh), "dyn");
    let sf_of = |lam: f64| {
        IntegratorSpec::Sf(SfConfig {
            kernel: KernelFn::ExpNeg(lam),
            threshold: 64,
            ..Default::default()
        })
    };
    let field = rand_field(n, 3, 79);
    // Warm two kernel-stage variants over one shared tree.
    eng.integrate(id, &sf_of(1.0), &field).unwrap();
    eng.integrate(id, &sf_of(3.0), &field).unwrap();
    assert_eq!(eng.cache_stats().structures.entries, 1);

    let verts = gfi::mesh::radial_bump(&mesh.verts, 31, n / 100, 0.04);
    let info = eng
        .update_cloud(id, gfi::pointcloud::PointCloud::new(verts), &UpdateOpts::default())
        .unwrap();
    assert_eq!(info.epoch, 1);
    assert_eq!(info.refreshed, 2, "both kernel variants must migrate: {info:?}");
    assert_eq!(info.dropped, 0, "{info:?}");
    // The tree was refreshed *once*: the node counters account for
    // exactly one tree (reused + rebuilt == total), not one per variant.
    let updated = eng.cloud(id).unwrap().scene.clone();
    let total_nodes = {
        // Downcast-free: a fresh build reports every node as rebuilt.
        let st = gfi::integrators::sf::SfStructure::build(
            updated.graph.as_ref().unwrap(),
            gfi::integrators::sf::SfTreeParams::of(&SfConfig {
                threshold: 64,
                ..Default::default()
            }),
        );
        st.stats().leaves + st.stats().internals
    };
    assert_eq!(
        info.reused_nodes + info.rebuilt_nodes,
        total_nodes,
        "structure must be refreshed exactly once, not per kernel variant: {info:?}"
    );
    assert!(info.reused_nodes * 2 > total_nodes, "{info:?}");
    assert_eq!(eng.cache_stats().structures.entries, 1, "one refreshed tree survives");

    // Migrated integrators serve bitwise-identical to fresh prepares…
    for lam in [1.0, 3.0] {
        let (out, served) = eng.integrate(id, &sf_of(lam), &field).unwrap();
        assert!(served.cache_hit, "migrated kernel variant must be pre-warmed");
        let fresh = prepare(&updated, &sf_of(lam)).unwrap();
        assert_eq!(out.data, fresh.apply(&field).data, "lam={lam}");
    }
    // …and a *new* kernel after the update shares the refreshed tree.
    let (out_new, info_new) = eng.integrate(id, &sf_of(8.0), &field).unwrap();
    assert!(!info_new.cache_hit);
    assert!(
        info_new.structure_shared,
        "post-update kernel sweep must share the refreshed structure"
    );
    let fresh_new = prepare(&updated, &sf_of(8.0)).unwrap();
    assert_eq!(out_new.data, fresh_new.apply(&field).data);
}

/// ISSUE 4 acceptance, scaled to the test budget (the ≥10k-node version
/// of the same check — bitwise parity plus majority tree reuse plus the
/// refresh-vs-reprepare timing — runs in `bench_coordinator`'s
/// `engine/update_frame` case): a 1%-vertex perturbation of a mesh
/// served through `update_cloud` must (a) migrate every refreshable
/// cached integrator into the new epoch, (b) reuse the majority of the
/// SF separator tree, and (c) serve results bitwise-identical to a full
/// `prepare` on the updated scene.
#[test]
fn dynamic_scene_update_is_bitwise_identical_and_reuses_majority() {
    let mut mesh = gfi::mesh::icosphere(4); // 2562 vertices
    mesh.normalize_unit_box();
    let n = mesh.num_verts();
    let eng = Engine::new(None);
    let id = eng.register_scene(Scene::from_mesh(&mesh), "dyn");
    let sf = IntegratorSpec::Sf(SfConfig { threshold: 256, separator_size: 8, ..Default::default() });
    let rfd = IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() });
    let field = rand_field(n, 3, 41);
    eng.integrate(id, &sf, &field).unwrap();
    eng.integrate(id, &rfd, &field).unwrap();

    // Deform ~1% of the vertices in one geometric neighborhood.
    let verts = gfi::mesh::radial_bump(&mesh.verts, 123, n / 100, 0.04);
    let info = eng
        .update_cloud(id, gfi::pointcloud::PointCloud::new(verts), &UpdateOpts::default())
        .unwrap();
    assert_eq!(info.epoch, 1);
    assert_eq!(info.refreshed, 2, "SF and RFD must both migrate: {info:?}");
    assert_eq!(info.dropped, 0, "{info:?}");
    let total = info.reused_nodes + info.rebuilt_nodes;
    assert!(
        info.reused_nodes * 2 > total,
        "majority of the separator tree must be reused, got {}/{total}",
        info.reused_nodes
    );

    let updated = eng.cloud(id).unwrap().scene.clone();
    for spec in [&sf, &rfd] {
        let (out, served) = eng.integrate(id, spec, &field).unwrap();
        assert!(served.cache_hit, "{spec:?} must be served by the refreshed artifact");
        let fresh = prepare(&updated, spec).unwrap();
        assert_eq!(
            out.data,
            fresh.apply(&field).data,
            "{spec:?}: refreshed artifact diverged from a fresh prepare"
        );
    }
}

/// Every `*.art` spill file under `dir/structures/`.
fn store_files(dir: &std::path::Path) -> Vec<std::path::PathBuf> {
    let mut out = Vec::new();
    if let Ok(clouds) = std::fs::read_dir(dir.join("structures")) {
        for cd in clouds.flatten() {
            if let Ok(files) = std::fs::read_dir(cd.path()) {
                for f in files.flatten() {
                    if f.path().extension().map_or(false, |e| e == "art") {
                        out.push(f.path());
                    }
                }
            }
        }
    }
    out
}

/// ISSUE 7 acceptance (warm restart): a fresh engine pointed at the
/// previous engine's artifacts dir serves every spec with **zero
/// structure builds** — each structure stage is a validated disk load
/// (`disk_hits` = distinct structural keys) — bitwise-identical both to
/// the pre-restart outputs and to a from-scratch `prepare` oracle. The
/// restarted engine is armed with a tripwire fault plan at
/// `site=prepare`, so any `prepare_structure` call would fail its
/// request: all-requests-succeed *proves* the structure stage never ran.
#[test]
fn warm_restart_serves_from_disk_with_zero_structure_builds() {
    let dir = std::env::temp_dir().join(format!("gfi_restart_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sf_of = |lam: f64| {
        IntegratorSpec::Sf(SfConfig {
            kernel: KernelFn::ExpNeg(lam),
            threshold: 64,
            ..Default::default()
        })
    };
    // 5 specs across 3 backends → 3 distinct structural keys (SF tree,
    // BF-sp distance matrix, RFD features).
    let specs = vec![
        sf_of(1.0),
        sf_of(4.0),
        IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0)),
        IntegratorSpec::BfSp(KernelFn::GaussianSq(1.5)),
        IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
    ];

    // Engine A: prepare everything with the store on, then die.
    let (n, outs_a) = {
        let a = EngineConfig::default().artifacts(&dir).store(true).build();
        assert!(a.config_warnings().is_empty(), "{:?}", a.config_warnings());
        let id = a.register_mesh(gfi::mesh::icosphere(2), "sphere");
        let n = a.cloud(id).unwrap().scene.len();
        let field = rand_field(n, 3, 90);
        let outs: Vec<Mat> = specs
            .iter()
            .map(|s| a.integrate(id, s, &field).unwrap().0)
            .collect();
        let s = a.store_stats().unwrap();
        assert_eq!(s.spills, 3, "one write-through spill per structural key: {s:?}");
        assert_eq!(s.files, 3, "{s:?}");
        (n, outs)
    }; // drop(a): the RAM tier dies with the process, the disk tier survives.

    // Engine B: same dir, tripwire armed.
    let trip =
        gfi::coordinator::faults::FaultPlan::parse("site=prepare,kind=error,times=1000")
            .unwrap();
    let b = EngineConfig::default()
        .artifacts(&dir)
        .store(true)
        .fault_plan(trip)
        .build();
    let id = b.register_mesh(gfi::mesh::icosphere(2), "sphere");
    let scene = b.cloud(id).unwrap().scene.clone();
    let field = rand_field(n, 3, 90);
    for (spec, want) in specs.iter().zip(&outs_a) {
        let (out, info) = b
            .integrate(id, spec, &field)
            .unwrap_or_else(|e| panic!("{spec:?}: restart must not rebuild structures: {e}"));
        assert!(!info.cache_hit);
        assert!(info.structure_shared, "{spec:?}: structure must come from disk or RAM");
        assert_eq!(out.data, want.data, "{spec:?}: restarted result diverged");
        let fresh = prepare(&scene, spec).unwrap();
        assert_eq!(out.data, fresh.apply(&field).data, "{spec:?}: vs fresh-prepare oracle");
    }
    let s = b.store_stats().unwrap();
    assert_eq!(s.disk_hits, 3, "each structural key loads from disk exactly once: {s:?}");
    assert_eq!((s.invalid_files, s.io_errors), (0, 0), "{s:?}");
    assert_eq!(b.faults().injected(), 0, "tripwire fired: a structure was rebuilt");
    let _ = std::fs::remove_dir_all(&dir);
}

/// ISSUE 7 acceptance (validation ladder): a corrupt (flipped byte),
/// truncated, wrong-epoch, or wrong-version spill file is rejected by
/// the restarted engine — `invalid_files` bumps, the request
/// transparently recomputes bitwise-identically — and the recompute's
/// write-through spill *heals* the slot, so the next restart serves
/// from disk again.
#[test]
fn doctored_store_files_degrade_to_recompute_bitwise() {
    use gfi::coordinator::store::{OFF_EPOCH, OFF_VERSION};
    let cases: [(&str, fn(&mut Vec<u8>)); 4] = [
        ("corrupt", |b| {
            let i = b.len() - 1;
            b[i] ^= 0x40;
        }),
        ("truncated", |b| b.truncate(b.len() / 2)),
        ("wrong_epoch", |b| b[OFF_EPOCH] = b[OFF_EPOCH].wrapping_add(1)),
        ("wrong_version", |b| b[OFF_VERSION] = b[OFF_VERSION].wrapping_add(1)),
    ];
    let spec = IntegratorSpec::Sf(SfConfig { threshold: 32, ..Default::default() });
    for (tag, doctor) in cases {
        let dir = std::env::temp_dir()
            .join(format!("gfi_doctor_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let want = {
            let a = EngineConfig::default().artifacts(&dir).store(true).build();
            let id = a.register_mesh(gfi::mesh::icosphere(1), "s");
            let n = a.cloud(id).unwrap().scene.len();
            a.integrate(id, &spec, &rand_field(n, 2, 91)).unwrap().0
        };
        let files = store_files(&dir);
        assert_eq!(files.len(), 1, "{tag}: expected exactly one spill file");
        let mut bytes = std::fs::read(&files[0]).unwrap();
        doctor(&mut bytes);
        std::fs::write(&files[0], &bytes).unwrap();

        let b = EngineConfig::default().artifacts(&dir).store(true).build();
        let id = b.register_mesh(gfi::mesh::icosphere(1), "s");
        let n = b.cloud(id).unwrap().scene.len();
        let (out, info) = b.integrate(id, &spec, &rand_field(n, 2, 91)).unwrap();
        assert!(!info.structure_shared, "{tag}: an invalid file must never serve");
        let s = b.store_stats().unwrap();
        assert_eq!(s.invalid_files, 1, "{tag}: {s:?}");
        assert_eq!(s.disk_hits, 0, "{tag}: {s:?}");
        assert_eq!(out.data, want.data, "{tag}: recompute diverged");

        // The write-through spill of the recompute replaced the bad
        // file: a second restart serves from disk again.
        let c = EngineConfig::default().artifacts(&dir).store(true).build();
        let id = c.register_mesh(gfi::mesh::icosphere(1), "s");
        let (out2, info2) = c.integrate(id, &spec, &rand_field(n, 2, 91)).unwrap();
        assert!(info2.structure_shared, "{tag}: healed slot must serve from disk");
        assert_eq!(c.store_stats().unwrap().disk_hits, 1, "{tag}");
        assert_eq!(out2.data, want.data, "{tag}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// PR 8 acceptance (precision policy, keys + footprint): each precision
/// variant of a dense-storage spec gets its own cache identity, the f32
/// policies report roughly half the resident bytes of f64 on the dense
/// backends, track the f64 results closely, and the two f32 policies
/// share one quantized structure (one structural key) while staying
/// distinct cache entries.
#[test]
fn precision_policies_have_distinct_keys_and_half_the_footprint() {
    let scene = mesh_scene();
    let n = scene.len();
    let field = rand_field(n, 3, 92);
    let bases = [
        IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0)),
        IntegratorSpec::BfDiffusion { epsilon: 0.2, lambda: -0.2 },
        IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
    ];
    for base in &bases {
        let f32_spec = IntegratorSpec::with_precision(Precision::F32, base.clone());
        let acc_spec = IntegratorSpec::with_precision(Precision::F32AccF64, base.clone());
        // Three distinct cache identities…
        let keys = [
            base.cache_key().unwrap(),
            f32_spec.cache_key().unwrap(),
            acc_spec.cache_key().unwrap(),
        ];
        assert_ne!(keys[0], keys[1], "{base:?}");
        assert_ne!(keys[1], keys[2], "{base:?}");
        assert_ne!(keys[0], keys[2], "{base:?}");
        // …but the two f32 policies share one quantized structure.
        assert_eq!(
            f32_spec.structural_key(),
            acc_spec.structural_key(),
            "{base:?}: F32 and F32AccF64 must share a structure"
        );
        let i64 = prepare(&scene, base).unwrap();
        let i32_ = prepare(&scene, &f32_spec).unwrap();
        let iacc = prepare(&scene, &acc_spec).unwrap();
        // f32 storage shrinks the footprint; on the dense-table backends
        // (BF) it is within rounding of exactly half.
        assert!(
            i32_.resident_bytes() < i64.resident_bytes(),
            "{base:?}: f32 {} vs f64 {}",
            i32_.resident_bytes(),
            i64.resident_bytes()
        );
        if matches!(base, IntegratorSpec::BfSp(_) | IntegratorSpec::BfDiffusion { .. }) {
            assert!(
                i32_.resident_bytes() * 10 <= i64.resident_bytes() * 6,
                "{base:?}: dense f32 table must be ~half: {} vs {}",
                i32_.resident_bytes(),
                i64.resident_bytes()
            );
        }
        assert_eq!(i32_.resident_bytes(), iacc.resident_bytes(), "{base:?}");
        // Quantized results track f64 closely.
        let want = i64.apply(&field);
        let scale = want.data.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1e-30);
        for got in [i32_.apply(&field), iacc.apply(&field)] {
            let max_abs = want
                .data
                .iter()
                .zip(&got.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            assert!(max_abs / scale < 1e-4, "{base:?}: rel err {}", max_abs / scale);
        }
    }
    // Engine level: the three variants occupy three cache entries.
    let engine = Engine::new(None);
    let id = engine.register_mesh(gfi::mesh::icosphere(1), "s");
    let n = engine.cloud(id).unwrap().scene.len();
    let field = rand_field(n, 2, 93);
    let base = IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0));
    for spec in [
        base.clone(),
        IntegratorSpec::with_precision(Precision::F32, base.clone()),
        IntegratorSpec::with_precision(Precision::F32AccF64, base),
    ] {
        let (_, first) = engine.integrate(id, &spec, &field).unwrap();
        assert!(!first.cache_hit, "{spec:?}");
        let (_, second) = engine.integrate(id, &spec, &field).unwrap();
        assert!(second.cache_hit, "{spec:?}");
    }
    assert_eq!(engine.cache_stats().integrators.entries, 3);
}

/// PR 8 acceptance (f32 artifacts on disk): quantized structures
/// round-trip the store codec bitwise, and a warm restart serves the f32
/// specs from disk with zero structure builds (tripwire-proven), bitwise
/// identical to the pre-restart outputs.
#[test]
fn f32_artifacts_roundtrip_bitwise_and_survive_warm_restart() {
    let scene = mesh_scene();
    let specs = [
        IntegratorSpec::with_precision(Precision::F32, IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0))),
        IntegratorSpec::with_precision(
            Precision::F32AccF64,
            IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
        ),
    ];
    // Codec round-trip is bitwise: encode → decode → re-encode yields
    // identical bytes.
    for spec in &specs {
        let art = prepare_structure(&scene, spec).unwrap().unwrap();
        assert!(art.kind().ends_with("_f32"), "{spec:?} must build a quantized structure");
        let mut w = gfi::util::codec::Writer::new();
        art.encode_payload(&mut w);
        let bytes = w.into_bytes();
        let decoded =
            StructureArtifact::decode_payload(&mut gfi::util::codec::Reader::new(&bytes))
                .unwrap();
        assert_eq!(decoded.kind(), art.kind());
        let mut w2 = gfi::util::codec::Writer::new();
        decoded.encode_payload(&mut w2);
        assert_eq!(bytes, w2.into_bytes(), "{spec:?}: f32 artifact round-trip not bitwise");
    }

    let dir = std::env::temp_dir().join(format!("gfi_f32_restart_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (n, outs_a) = {
        let a = EngineConfig::default().artifacts(&dir).store(true).build();
        let id = a.register_mesh(gfi::mesh::icosphere(2), "sphere");
        let n = a.cloud(id).unwrap().scene.len();
        let field = rand_field(n, 3, 94);
        let outs: Vec<Mat> =
            specs.iter().map(|s| a.integrate(id, s, &field).unwrap().0).collect();
        let s = a.store_stats().unwrap();
        assert_eq!(s.spills, 2, "one spill per quantized structural key: {s:?}");
        (n, outs)
    };
    let trip = gfi::coordinator::faults::FaultPlan::parse("site=prepare,kind=error,times=1000")
        .unwrap();
    let b = EngineConfig::default().artifacts(&dir).store(true).fault_plan(trip).build();
    let id = b.register_mesh(gfi::mesh::icosphere(2), "sphere");
    let field = rand_field(n, 3, 94);
    for (spec, want) in specs.iter().zip(&outs_a) {
        let (out, info) = b
            .integrate(id, spec, &field)
            .unwrap_or_else(|e| panic!("{spec:?}: restart must not rebuild: {e}"));
        assert!(info.structure_shared, "{spec:?}: quantized structure must come from disk");
        assert_eq!(out.data, want.data, "{spec:?}: restarted f32 result diverged");
    }
    assert_eq!(b.store_stats().unwrap().disk_hits, 2);
    assert_eq!(b.faults().injected(), 0, "tripwire fired: a structure was rebuilt");
    let _ = std::fs::remove_dir_all(&dir);
}

/// PR 8 acceptance (codec hardening): a seeded byte-flip/truncate fuzz
/// loop over encoded artifacts of **every** `StructureArtifact` family
/// must never panic in `decode_payload` — every malformed buffer is a
/// typed `CodecError` (or decodes cleanly when the flip only touched
/// payload data bits) — and a doctored spill file of a quantized
/// artifact degrades to a counted soft miss in the store ladder.
#[test]
fn codec_fuzz_never_panics_across_all_artifact_families() {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let scene = mesh_scene();
    let family_specs = [
        IntegratorSpec::Sf(SfConfig { threshold: 16, ..Default::default() }),
        IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0)),
        IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
        IntegratorSpec::Trees { kind: TreeKind::Bartal, count: 2, lambda: 2.0, seed: 1 },
        IntegratorSpec::BfDiffusion { epsilon: 0.2, lambda: -0.2 },
        IntegratorSpec::with_precision(Precision::F32, IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0))),
        IntegratorSpec::with_precision(
            Precision::F32,
            IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
        ),
    ];
    let mut kinds = Vec::new();
    let mut rng = Rng::new(2024);
    for spec in &family_specs {
        let art = prepare_structure(&scene, spec).unwrap().unwrap();
        kinds.push(art.kind());
        let mut w = gfi::util::codec::Writer::new();
        art.encode_payload(&mut w);
        let clean = w.into_bytes();
        // Sanity: the clean buffer decodes.
        StructureArtifact::decode_payload(&mut gfi::util::codec::Reader::new(&clean))
            .unwrap_or_else(|e| panic!("{}: clean buffer failed to decode: {e:?}", art.kind()));
        for _ in 0..120 {
            let mut bytes = clean.clone();
            match rng.below(3) {
                0 => {
                    let i = rng.below(bytes.len());
                    bytes[i] ^= 1 << rng.below(8);
                }
                1 => bytes.truncate(rng.below(bytes.len() + 1)),
                _ => {
                    let i = rng.below(bytes.len());
                    bytes[i] ^= 1 << rng.below(8);
                    bytes.truncate(rng.below(bytes.len() + 1));
                }
            }
            let kind = art.kind();
            let res = catch_unwind(AssertUnwindSafe(|| {
                StructureArtifact::decode_payload(&mut gfi::util::codec::Reader::new(&bytes))
                    .map(|a| a.kind())
            }));
            // The decode may succeed or fail — but it must never panic.
            assert!(res.is_ok(), "{kind}: decode_payload panicked on doctored bytes");
        }
    }
    // Every artifact family was covered, including the quantized ones.
    for want in [
        "sf_tree",
        "distances",
        "rfd_features",
        "trees",
        "eps_graph",
        "distances_f32",
        "rfd_features_f32",
    ] {
        assert!(kinds.contains(&want), "fuzz loop missed family {want}: {kinds:?}");
    }

    // Store-ladder integration: a flipped byte in a *quantized* spill
    // file is a counted soft miss and the request recomputes bitwise.
    let spec = IntegratorSpec::with_precision(
        Precision::F32,
        IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0)),
    );
    let dir = std::env::temp_dir().join(format!("gfi_f32_doctor_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let want = {
        let a = EngineConfig::default().artifacts(&dir).store(true).build();
        let id = a.register_mesh(gfi::mesh::icosphere(1), "s");
        let n = a.cloud(id).unwrap().scene.len();
        a.integrate(id, &spec, &rand_field(n, 2, 95)).unwrap().0
    };
    let files = store_files(&dir);
    assert_eq!(files.len(), 1);
    let mut bytes = std::fs::read(&files[0]).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&files[0], &bytes).unwrap();
    let b = EngineConfig::default().artifacts(&dir).store(true).build();
    let id = b.register_mesh(gfi::mesh::icosphere(1), "s");
    let n = b.cloud(id).unwrap().scene.len();
    let (out, info) = b.integrate(id, &spec, &rand_field(n, 2, 95)).unwrap();
    assert!(!info.structure_shared, "doctored f32 spill must not serve");
    assert_eq!(b.store_stats().unwrap().invalid_files, 1);
    assert_eq!(out.data, want.data, "f32 recompute diverged");
    let _ = std::fs::remove_dir_all(&dir);
}

/// PR 8 acceptance (non-finite distance propagation): on a disconnected
/// graph, unreachable pairs are `∞` in the f64 distance matrix, stay `∞`
/// through the f32 quantization, and contribute exactly `0` under every
/// precision policy — a field supported on one component never leaks
/// into the other.
#[test]
fn disconnected_graphs_contribute_zero_in_every_precision() {
    use gfi::graph::CsrGraph;
    use gfi::integrators::artifacts;
    // Two 4-cliques with no bridge.
    let n = 8;
    let mut edges = Vec::new();
    for base in [0usize, 4] {
        for i in 0..4 {
            for j in (i + 1)..4 {
                edges.push((base + i, base + j, 0.5 + 0.1 * (i + j) as f64));
            }
        }
    }
    let g = CsrGraph::from_edges(n, &edges);
    let mut rng = Rng::new(96);
    let pts: Vec<[f64; 3]> =
        (0..n).map(|_| [rng.uniform(), rng.uniform(), rng.uniform()]).collect();
    let scene = Scene::new(gfi::pointcloud::PointCloud::new(pts), Some(g.clone()));

    // The quantization preserves ∞ exactly where the f64 matrix has it.
    let d64 = artifacts::graph_distance_matrix(&g);
    let d32 = artifacts::distances_to_f32(&d64);
    for (a, b) in d64.data.iter().zip(&d32.data) {
        assert_eq!(a.is_finite(), b.is_finite(), "quantization changed reachability");
        if !a.is_finite() {
            assert_eq!(*b, f32::INFINITY);
        }
    }
    let k32 = artifacts::sp_kernel_map_f32(&d32, &KernelFn::ExpNeg(1.0));
    for (d, k) in d32.data.iter().zip(&k32.data) {
        if *d == f32::INFINITY {
            assert_eq!(*k, 0.0, "unreachable pair must contribute zero in f32");
        }
    }

    // Field = 1 on the first component, 0 on the second: every precision
    // policy must leave the second component's output at exactly 0.
    let mut field = Mat::zeros(n, 1);
    for i in 0..4 {
        field[(i, 0)] = 1.0;
    }
    let base = IntegratorSpec::BfSp(KernelFn::ExpNeg(1.0));
    for spec in [
        base.clone(),
        IntegratorSpec::with_precision(Precision::F32, base.clone()),
        IntegratorSpec::with_precision(Precision::F32AccF64, base),
    ] {
        let integ = prepare(&scene, &spec).unwrap();
        let out = integ.apply(&field);
        for i in 4..8 {
            assert_eq!(
                out[(i, 0)],
                0.0,
                "{spec:?}: disconnected component received mass"
            );
        }
        for i in 0..4 {
            assert!(out[(i, 0)] > 0.0, "{spec:?}: connected component lost its mass");
        }
    }
}
