//! Chaos suite for the fault-tolerance layer (docs/ARCHITECTURE.md,
//! "Failure model"): a seeded fault plan injecting panics, spurious
//! errors, slow stages, artifact corruption, and connection drops across
//! five backends and the server accept/read path is run against a live
//! server. The acceptance contract:
//!
//! * no worker thread dies — the server keeps answering after every
//!   injected fault, and its reaped-thread backlog stays small;
//! * every failed request yields a *well-formed typed* error
//!   (`code` + `retryable`, with `retry_after_ms` on degradation);
//! * quarantined keys recover once the fault clears (backoff retry, or
//!   the epoch bump of the next `update_cloud`);
//! * after the plan is exhausted, results are **bitwise-identical** to
//!   an unfaulted engine serving the same requests;
//! * persistence-tier faults (`site=spill` / `site=load`) are soft by
//!   construction — they can cost disk hits, never correctness.

use gfi::coordinator::faults::FaultPlan;
use gfi::coordinator::{server, Engine, EngineConfig, RequestOpts, UpdateOpts};
use gfi::integrators::{GfiError, IntegratorSpec};
use gfi::linalg::Mat;
use gfi::util::json::{parse, Json};
use gfi::util::rng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn spawn_server(
    engine: Arc<Engine>,
    cfg: server::ServerConfig,
) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        server::serve_with(engine, "127.0.0.1:0", cfg, move |a| {
            addr_tx.send(a).unwrap();
        })
        .unwrap();
    });
    (addr_rx.recv().unwrap(), handle)
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// One request/response; `Err` on any transport failure (dropped
    /// connection, EOF mid-response).
    fn send(&mut self, line: &str) -> std::io::Result<Json> {
        writeln!(self.stream, "{line}")?;
        let mut resp = String::new();
        if self.reader.read_line(&mut resp)? == 0 {
            return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "eof"));
        }
        parse(&resp)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }
}

/// Sends with the documented client recovery loop: reconnect on injected
/// connection drops, back off and retry on typed retryable errors.
/// Panics when a failure response is malformed (missing `code` /
/// `retryable`) or a non-retryable error arrives — both acceptance
/// violations.
fn send_with_retry(addr: std::net::SocketAddr, client: &mut Client, req: &str) -> Json {
    for _ in 0..80 {
        let resp = match client.send(req) {
            Ok(r) => r,
            Err(_) => {
                std::thread::sleep(std::time::Duration::from_millis(2));
                *client = Client::connect(addr).expect("reconnect");
                continue;
            }
        };
        if resp.get("ok").and_then(Json::as_bool) == Some(true) {
            return resp;
        }
        let code = resp.get("code").and_then(Json::as_str);
        let retryable = resp.get("retryable").and_then(Json::as_bool);
        assert!(
            code.is_some() && retryable.is_some(),
            "malformed error response: {resp}"
        );
        assert_eq!(retryable, Some(true), "non-retryable failure for {req}: {resp}");
        let backoff = resp
            .get("retry_after_ms")
            .and_then(Json::as_usize)
            .unwrap_or(2) as u64;
        std::thread::sleep(std::time::Duration::from_millis(backoff.clamp(1, 100)));
    }
    panic!("request never recovered: {req}");
}

/// The wire request for workload variant `v` (cycled per client). The
/// two `sf` lambdas share one balanced-separator structure, so the
/// second spec exercises the structure-store hit path (and its `corrupt`
/// rule). Fields are formatted with `{}` — the shortest exact f64 form —
/// so the oracle engine sees bitwise-identical inputs.
fn request_for(v: usize, cloud: usize, field: &[f64]) -> String {
    let fj = field.iter().map(|x| format!("{x}")).collect::<Vec<_>>().join(",");
    match v % 6 {
        0 => format!(
            r#"{{"op":"integrate","cloud":{cloud},"backend":"sf","field":[{fj}],"d":1,"lambda":2.0,"threshold":16}}"#
        ),
        1 => format!(
            r#"{{"op":"integrate","cloud":{cloud},"backend":"sf","field":[{fj}],"d":1,"lambda":4.0,"threshold":16}}"#
        ),
        2 => format!(
            r#"{{"op":"integrate","cloud":{cloud},"backend":"rfd","field":[{fj}],"d":1,"m":8}}"#
        ),
        3 => format!(
            r#"{{"op":"integrate","cloud":{cloud},"backend":"bf_sp","field":[{fj}],"d":1,"lambda":2.0}}"#
        ),
        4 => format!(
            r#"{{"op":"integrate","cloud":{cloud},"backend":"bf_diffusion","field":[{fj}],"d":1,"epsilon":0.25,"lambda":-0.2}}"#
        ),
        _ => format!(
            r#"{{"op":"integrate","cloud":{cloud},"backend":"trees_bartal","field":[{fj}],"d":1,"count":3,"lambda":2.0,"seed":1}}"#
        ),
    }
}

/// The acceptance chaos run: a seeded plan worth 25+ fault fires
/// (panics, spurious errors, slow stages, artifact corruption,
/// connection drops) across five backends plus the server accept/read
/// path, absorbed by two concurrent retrying clients.
#[test]
fn chaos_plan_recovers_to_bitwise_identical_results() {
    const PLAN: &str = "seed=11;\
        site=prepare,backend=sf,kind=panic,times=3;\
        site=finish,backend=sf,kind=error,times=3;\
        site=prepare,backend=rfd,kind=panic,times=3;\
        site=apply,backend=rfd,kind=panic,times=3;\
        site=apply,backend=bf_sp,kind=delay,ms=2,times=4;\
        site=prepare,backend=bf_diffusion,kind=error,times=3;\
        site=apply,backend=trees,kind=panic,times=2;\
        site=structure_hit,backend=sf,kind=corrupt,times=2;\
        site=accept,kind=drop,times=2;\
        site=read,kind=drop,times=2,every=4";
    let plan = FaultPlan::parse(PLAN).unwrap();
    assert!(plan.rules.iter().map(|r| r.times).sum::<u64>() >= 20);

    // Unfaulted oracle: same mesh (register_mesh is deterministic), same
    // specs, same fields.
    let clean = Arc::new(EngineConfig::default().fault_plan(FaultPlan::default()).build());
    let clean_id = clean.register_mesh(gfi::mesh::icosphere(1), "chaos");
    let n = clean.cloud(clean_id).unwrap().scene.len();

    let engine = Arc::new(
        EngineConfig::default()
            .fault_plan(plan)
            .quarantine_attempts(10) // deeper than any rule's panic budget
            .quarantine_backoff_ms(1)
            .build(),
    );
    let (addr, server_thread) = spawn_server(engine.clone(), server::ServerConfig::default());

    let mut ctl = Client::connect(addr).unwrap();
    let reg = send_with_retry(
        addr,
        &mut ctl,
        r#"{"op":"register_mesh","kind":"icosphere","param":1,"name":"chaos"}"#,
    );
    let cloud = reg.get("id").unwrap().as_usize().unwrap();

    std::thread::scope(|s| {
        let clean = &clean;
        for cid in 0..2usize {
            s.spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                let mut rng = Rng::new(cid as u64 + 500);
                for r in 0..12usize {
                    let field: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
                    let req = request_for(r, cloud, &field);
                    let resp = send_with_retry(addr, &mut client, &req);
                    let got = resp.get("result").unwrap().as_f64_vec().unwrap();
                    let spec =
                        IntegratorSpec::from_request(&parse(&req).unwrap()).unwrap();
                    let f = Mat::from_vec(n, 1, field);
                    let (want, _) = clean.integrate(clean_id, &spec, &f).unwrap();
                    assert_eq!(
                        got, want.data,
                        "variant {r} diverged from the unfaulted engine"
                    );
                }
            });
        }
    });

    // The same server must still answer (no worker thread died), the plan
    // must actually have fired, and every quarantined key must have
    // recovered — its last rebuild succeeded and cleared the record.
    let health = send_with_retry(addr, &mut ctl, r#"{"op":"health"}"#);
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"), "{health}");
    let rb = health.get("robustness").unwrap();
    assert_eq!(rb.get("quarantined_live").unwrap().as_usize(), Some(0));
    assert!(rb.get("quarantines").unwrap().as_usize().unwrap() >= 1);
    let injected = engine.faults().injected();
    assert!(injected >= 20, "plan injected only {injected} faults");
    assert!(engine.robustness_stats().panics_caught >= 8, "panic rules under-fired");

    let stats = send_with_retry(addr, &mut ctl, r#"{"op":"stats"}"#);
    let backlog = stats
        .get("server")
        .unwrap()
        .get("worker_backlog")
        .unwrap()
        .as_usize()
        .unwrap();
    assert!(backlog <= 4, "worker threads accumulated under chaos: {backlog}");

    send_with_retry(addr, &mut ctl, r#"{"op":"shutdown"}"#);
    drop(ctl); // free the last worker so the accept loop can join it
    server_thread.join().unwrap();
}

/// ISSUE 10 chaos coverage for the evented binary transport: the same
/// accept/read drop+delay chaos the blocking server absorbs, plus
/// backend panics, fired at `serve_evented` while reconnecting *binary*
/// clients retry through it. The acceptance bar is unchanged: every
/// request eventually succeeds bitwise-identical to an unfaulted oracle
/// engine, failures cross the wire as typed retryable error frames, and
/// the server ends healthy with zero live quarantines — with the
/// micro-batching window live the whole time.
#[cfg(unix)]
#[test]
fn binary_transport_chaos_recovers_to_bitwise_identical_results() {
    use gfi::coordinator::evented;
    use gfi::coordinator::frame::{self, opcode};
    use std::io::Read;

    /// One-request-at-a-time binary client; any transport failure
    /// surfaces as `Err` so the retry loop can reconnect.
    struct BinClient {
        stream: TcpStream,
        buf: Vec<u8>,
    }

    impl BinClient {
        fn connect(addr: std::net::SocketAddr) -> std::io::Result<BinClient> {
            Ok(BinClient { stream: TcpStream::connect(addr)?, buf: Vec::new() })
        }

        fn request(&mut self, op: u8, id: u64, payload: &str) -> std::io::Result<Json> {
            let bad = |e: String| std::io::Error::new(std::io::ErrorKind::InvalidData, e);
            self.stream.write_all(&frame::encode(op, id, payload.as_bytes()))?;
            let mut chunk = [0u8; 16 * 1024];
            loop {
                match frame::decode(&self.buf) {
                    Ok(Some((f, used))) => {
                        self.buf.drain(..used);
                        assert_eq!(
                            (f.op, f.id),
                            (op, id),
                            "binary response must echo the request header"
                        );
                        let text =
                            String::from_utf8(f.payload).map_err(|e| bad(e.to_string()))?;
                        return parse(&text).map_err(|e| bad(e.to_string()));
                    }
                    Ok(None) => {}
                    Err(e) => return Err(bad(e.to_string())),
                }
                let n = self.stream.read(&mut chunk)?;
                if n == 0 {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "eof",
                    ));
                }
                self.buf.extend_from_slice(&chunk[..n]);
            }
        }
    }

    /// [`send_with_retry`] over binary frames: reconnect on injected
    /// connection drops, back off and retry on typed retryable errors.
    fn retry_bin(
        addr: std::net::SocketAddr,
        client: &mut BinClient,
        op: u8,
        next_id: &mut u64,
        payload: &str,
    ) -> Json {
        for _ in 0..80 {
            *next_id += 1;
            let resp = match client.request(op, *next_id, payload) {
                Ok(r) => r,
                Err(_) => {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    match BinClient::connect(addr) {
                        Ok(c) => *client = c,
                        Err(_) => {}
                    }
                    continue;
                }
            };
            if resp.get("ok").and_then(Json::as_bool) == Some(true) {
                return resp;
            }
            let code = resp.get("code").and_then(Json::as_str);
            let retryable = resp.get("retryable").and_then(Json::as_bool);
            assert!(
                code.is_some() && retryable.is_some(),
                "malformed error response: {resp}"
            );
            assert_eq!(retryable, Some(true), "non-retryable failure for {payload}: {resp}");
            let backoff = resp
                .get("retry_after_ms")
                .and_then(Json::as_usize)
                .unwrap_or(2) as u64;
            std::thread::sleep(std::time::Duration::from_millis(backoff.clamp(1, 100)));
        }
        panic!("binary request never recovered: {payload}");
    }

    /// [`request_for`]'s wire body minus the `"op"` key — binary frames
    /// carry the op in the header (every variant starts identically).
    fn payload_for(v: usize, cloud: usize, field: &[f64]) -> String {
        request_for(v, cloud, field).replacen("{\"op\":\"integrate\",", "{", 1)
    }

    const PLAN: &str = "seed=23;\
        site=accept,kind=drop,times=2;\
        site=accept,kind=delay,ms=2,times=2;\
        site=read,kind=drop,times=2,every=3;\
        site=read,kind=delay,ms=2,times=2;\
        site=prepare,backend=rfd,kind=panic,times=2;\
        site=apply,backend=sf,kind=panic,times=2";
    let plan = FaultPlan::parse(PLAN).unwrap();

    let clean = Arc::new(EngineConfig::default().fault_plan(FaultPlan::default()).build());
    let clean_id = clean.register_mesh(gfi::mesh::icosphere(1), "chaos-bin");
    let n = clean.cloud(clean_id).unwrap().scene.len();

    let engine = Arc::new(
        EngineConfig::default()
            .fault_plan(plan)
            .quarantine_attempts(10)
            .quarantine_backoff_ms(1)
            .build(),
    );
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let eng2 = engine.clone();
    let server_thread = std::thread::spawn(move || {
        evented::serve_evented_with(
            eng2,
            "127.0.0.1:0",
            server::ServerConfig::default(),
            move |a| {
                addr_tx.send(a).unwrap();
            },
        )
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap();

    let mut ctl = BinClient::connect(addr).unwrap();
    let mut ctl_id = 0u64;
    let reg = retry_bin(
        addr,
        &mut ctl,
        opcode::REGISTER_MESH,
        &mut ctl_id,
        r#"{"kind":"icosphere","param":1,"name":"chaos-bin"}"#,
    );
    let cloud = reg.get("id").unwrap().as_usize().unwrap();

    std::thread::scope(|s| {
        let clean = &clean;
        for cid in 0..2usize {
            s.spawn(move || {
                let mut client = BinClient::connect(addr).expect("connect");
                let mut req_id = (cid as u64 + 1) * 1000;
                let mut rng = Rng::new(cid as u64 + 900);
                for r in 0..12usize {
                    let field: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
                    let payload = payload_for(r, cloud, &field);
                    let resp = retry_bin(
                        addr,
                        &mut client,
                        opcode::INTEGRATE,
                        &mut req_id,
                        &payload,
                    );
                    let got = resp.get("result").unwrap().as_f64_vec().unwrap();
                    let spec =
                        IntegratorSpec::from_request(&parse(&payload).unwrap()).unwrap();
                    let f = Mat::from_vec(n, 1, field);
                    let (want, _) = clean.integrate(clean_id, &spec, &f).unwrap();
                    assert_eq!(
                        got, want.data,
                        "variant {r} diverged over the binary transport"
                    );
                }
            });
        }
    });

    // Still healthy: no worker died, every quarantined key recovered,
    // the plan actually fired, and the batching window — live the whole
    // run (default 1ms) — reports its counters over the wire.
    let health = retry_bin(addr, &mut ctl, opcode::HEALTH, &mut ctl_id, "{}");
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"), "{health}");
    let rb = health.get("robustness").unwrap();
    assert_eq!(rb.get("quarantined_live").unwrap().as_usize(), Some(0));
    let injected = engine.faults().injected();
    assert!(injected >= 8, "plan injected only {injected} faults");

    let stats = retry_bin(addr, &mut ctl, opcode::STATS, &mut ctl_id, "{}");
    assert_eq!(
        stats.get("batcher").unwrap().get("enabled"),
        Some(&Json::Bool(true)),
        "{stats}"
    );

    retry_bin(addr, &mut ctl, opcode::SHUTDOWN, &mut ctl_id, "{}");
    drop(ctl);
    server_thread.join().unwrap();
}

/// A key that keeps failing past `max_attempts` is *hard* quarantined —
/// typed error with no retry hint, waiting doesn't help — until the next
/// epoch (a good `update_cloud` frame) sweeps it and serving recovers.
#[test]
fn hard_quarantine_recovers_at_the_next_epoch() {
    let plan = FaultPlan::parse("site=prepare,backend=rfd,kind=panic,times=3").unwrap();
    let eng = EngineConfig::default()
        .fault_plan(plan)
        .quarantine_attempts(2)
        .quarantine_backoff_ms(0)
        .build();
    let raw = {
        let mut rng = Rng::new(3);
        gfi::pointcloud::random_cloud(40, &mut rng)
    };
    let id = eng.register_cloud(raw.clone(), "scan");
    let spec =
        IntegratorSpec::from_request(&parse(r#"{"backend":"rfd","m":8}"#).unwrap()).unwrap();
    let mut rng = Rng::new(77);
    let field = Mat::from_vec(40, 1, (0..40).map(|_| rng.gaussian()).collect());

    // Two injected panics reach max_attempts=2 → hard quarantine: the
    // third request is refused *without* consuming the remaining planned
    // fault, and waiting does not lift it.
    for _ in 0..2 {
        let err = eng.integrate(id, &spec, &field).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<GfiError>(),
            Some(GfiError::Internal { .. })
        ));
    }
    std::thread::sleep(std::time::Duration::from_millis(5));
    let err = eng.integrate(id, &spec, &field).unwrap_err();
    match err.downcast_ref::<GfiError>() {
        Some(GfiError::Quarantined { failures: 2, retry_after_ms: None, .. }) => {}
        other => panic!("expected hard quarantine, got {other:?}"),
    }
    assert_eq!(eng.faults().injected(), 2, "hard quarantine must gate the rebuild");

    // A good frame bumps the epoch and sweeps the record. The planned
    // fault has one fire left: it burns on the first post-sweep rebuild,
    // and the retry after it serves — bitwise-identical to a clean engine
    // fed the same registration + frame.
    let mut moved = raw;
    moved.points[5][1] += 0.01;
    eng.update_cloud(id, moved.clone(), &UpdateOpts::default()).unwrap();
    let err = eng.integrate(id, &spec, &field).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<GfiError>(),
        Some(GfiError::Internal { .. })
    ));
    let (out, _) = eng.integrate(id, &spec, &field).unwrap();
    assert_eq!(eng.robustness_stats().quarantined_live, 0);

    let clean = EngineConfig::default().fault_plan(FaultPlan::default()).build();
    let cid = clean.register_cloud(
        {
            let mut rng = Rng::new(3);
            gfi::pointcloud::random_cloud(40, &mut rng)
        },
        "scan",
    );
    clean.update_cloud(cid, moved, &UpdateOpts::default()).unwrap();
    let (want, _) = clean.integrate(cid, &spec, &field).unwrap();
    assert_eq!(out.data, want.data, "post-recovery result diverged");
}

/// `max_inflight_prepares: 0` sheds every cache-miss prepare with the
/// typed `overloaded` error and its retry hint — and shedding is pure
/// backpressure: it never quarantines the refused key.
#[test]
fn zero_inflight_budget_sheds_all_prepares_with_typed_errors() {
    let eng = EngineConfig::default()
        .fault_plan(FaultPlan::default())
        .max_inflight_prepares(0)
        .build();
    let id = eng.register_mesh(gfi::mesh::icosphere(1), "s");
    let n = eng.cloud(id).unwrap().scene.len();
    let field = Mat::from_vec(n, 1, vec![1.0; n]);
    let spec =
        IntegratorSpec::from_request(&parse(r#"{"backend":"sf","lambda":2.0}"#).unwrap())
            .unwrap();
    assert!(eng.is_shedding());
    for _ in 0..3 {
        let err = eng
            .integrate_opts(id, &spec, &field, &RequestOpts::default())
            .unwrap_err();
        match err.downcast_ref::<GfiError>() {
            Some(GfiError::Overloaded { retry_after_ms, .. }) => assert!(*retry_after_ms > 0),
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }
    assert_eq!(eng.robustness_stats().sheds, 3);
    assert_eq!(eng.robustness_stats().quarantined_live, 0, "sheds must not quarantine");
}

/// ISSUE 7 chaos coverage for the persistence tier: a seeded plan fires
/// every store fault kind — `spill` error/corrupt/truncate/delay on the
/// writing engine, then `load` error/corrupt/truncate/delay on a
/// restarted engine — across the five structural backends. The contract
/// is "the store can lose performance but never correctness": every
/// request succeeds bitwise-identical to an unfaulted oracle, every
/// mangled file is rejected by the validation ladder (typed counter
/// bump) and healed by the recompute's write-through spill, and a
/// third, unfaulted restart serves everything from disk.
#[test]
fn store_chaos_degrades_softly_and_heals() {
    use gfi::integrators::rfd::RfdConfig;
    use gfi::integrators::sf::SfConfig;
    use gfi::integrators::trees::TreeKind;
    use gfi::integrators::KernelFn;

    let dir = std::env::temp_dir().join(format!("gfi_store_chaos_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // Five structural backends → five spill (then load) attempts in a
    // fixed order; the plan's rules are consumed first-match in order.
    let specs = vec![
        IntegratorSpec::Sf(SfConfig { threshold: 16, ..Default::default() }),
        IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
        IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0)),
        IntegratorSpec::Trees { kind: TreeKind::Bartal, count: 3, lambda: 2.0, seed: 1 },
        IntegratorSpec::BfDiffusion { epsilon: 0.25, lambda: -0.2 },
    ];

    // Unfaulted, store-less oracle.
    let clean = EngineConfig::default().fault_plan(FaultPlan::default()).build();
    let cid = clean.register_mesh(gfi::mesh::icosphere(1), "chaos");
    let n = clean.cloud(cid).unwrap().scene.len();
    let field = {
        let mut rng = Rng::new(42);
        Mat::from_vec(n, 2, (0..n * 2).map(|_| rng.gaussian()).collect())
    };
    let want: Vec<Mat> =
        specs.iter().map(|s| clean.integrate(cid, s, &field).unwrap().0).collect();

    // Engine A: every spill fault kind fires once, in spec order.
    let spill_plan = FaultPlan::parse(
        "seed=5;site=spill,kind=error,times=1;site=spill,kind=corrupt,times=1;\
         site=spill,kind=truncate,times=1;site=spill,kind=delay,ms=1,times=1",
    )
    .unwrap();
    {
        let a = EngineConfig::default()
            .artifacts(&dir)
            .store(true)
            .fault_plan(spill_plan)
            .build();
        let id = a.register_mesh(gfi::mesh::icosphere(1), "chaos");
        for (spec, w) in specs.iter().zip(&want) {
            let (out, _) = a.integrate(id, spec, &field).unwrap();
            assert_eq!(out.data, w.data, "{spec:?}: spill fault leaked into serving");
        }
        let s = a.store_stats().unwrap();
        // error → failed write (nothing lands); corrupt/truncate land as
        // poisoned files; delay + the unfaulted fifth spill land clean.
        assert_eq!((s.spills, s.files, s.io_errors), (4, 4, 1), "{s:?}");
        assert_eq!(a.faults().injected(), 4, "spill rules under-fired");
    }

    // Engine B (restart): every load fault kind fires once, in spec
    // order. The on-disk population A left behind: Sf missing (failed
    // write), Rfd corrupt, BfSp torn, Trees good, BfDiffusion good.
    let load_plan = FaultPlan::parse(
        "seed=5;site=load,kind=error,times=1;site=load,kind=corrupt,times=1;\
         site=load,kind=truncate,times=1;site=load,kind=delay,ms=1,times=1",
    )
    .unwrap();
    {
        let b = EngineConfig::default()
            .artifacts(&dir)
            .store(true)
            .fault_plan(load_plan)
            .build();
        let id = b.register_mesh(gfi::mesh::icosphere(1), "chaos");
        for (spec, w) in specs.iter().zip(&want) {
            let (out, _) = b.integrate(id, spec, &field).unwrap();
            assert_eq!(out.data, w.data, "{spec:?}: load fault leaked into serving");
        }
        let s = b.store_stats().unwrap();
        // Sf: absent file → plain miss (no rule consumed — faults fire
        // only on bytes that were actually read). Rfd: injected read
        // error (io_error + miss). BfSp: torn file + injected flip →
        // ladder reject (invalid). Trees: good file, injected
        // truncation → ladder reject (invalid). BfDiffusion: delayed
        // but validates → the one disk hit.
        assert_eq!(s.disk_hits, 1, "{s:?}");
        assert_eq!((s.io_errors, s.invalid_files, s.disk_misses), (1, 2, 4), "{s:?}");
        // Every miss recomputed and re-spilled: the store is healed.
        assert_eq!((s.spills, s.files), (4, 5), "{s:?}");
        assert_eq!(b.faults().injected(), 4, "load rules under-fired");
    }

    // Engine C (second restart, no faults): fully warm — every
    // structure loads from disk, still bitwise-identical.
    let c = EngineConfig::default()
        .artifacts(&dir)
        .store(true)
        .fault_plan(FaultPlan::default())
        .build();
    let id = c.register_mesh(gfi::mesh::icosphere(1), "chaos");
    for (spec, w) in specs.iter().zip(&want) {
        let (out, info) = c.integrate(id, spec, &field).unwrap();
        assert!(info.structure_shared, "{spec:?}: healed store must serve from disk");
        assert_eq!(out.data, w.data, "{spec:?}: warm restart diverged");
    }
    let s = c.store_stats().unwrap();
    assert_eq!((s.disk_hits, s.invalid_files, s.io_errors), (5, 0, 0), "{s:?}");
    let _ = std::fs::remove_dir_all(&dir);
}
