//! Chaos suite for the fault-tolerance layer (docs/ARCHITECTURE.md,
//! "Failure model"): a seeded fault plan injecting panics, spurious
//! errors, slow stages, artifact corruption, and connection drops across
//! five backends and the server accept/read path is run against a live
//! server. The acceptance contract:
//!
//! * no worker thread dies — the server keeps answering after every
//!   injected fault, and its reaped-thread backlog stays small;
//! * every failed request yields a *well-formed typed* error
//!   (`code` + `retryable`, with `retry_after_ms` on degradation);
//! * quarantined keys recover once the fault clears (backoff retry, or
//!   the epoch bump of the next `update_cloud`);
//! * after the plan is exhausted, results are **bitwise-identical** to
//!   an unfaulted engine serving the same requests.

use gfi::coordinator::faults::FaultPlan;
use gfi::coordinator::{server, Engine, EngineConfig, RequestOpts, UpdateOpts};
use gfi::integrators::{GfiError, IntegratorSpec};
use gfi::linalg::Mat;
use gfi::util::json::{parse, Json};
use gfi::util::rng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn spawn_server(
    engine: Arc<Engine>,
    cfg: server::ServerConfig,
) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        server::serve_with(engine, "127.0.0.1:0", cfg, move |a| {
            addr_tx.send(a).unwrap();
        })
        .unwrap();
    });
    (addr_rx.recv().unwrap(), handle)
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// One request/response; `Err` on any transport failure (dropped
    /// connection, EOF mid-response).
    fn send(&mut self, line: &str) -> std::io::Result<Json> {
        writeln!(self.stream, "{line}")?;
        let mut resp = String::new();
        if self.reader.read_line(&mut resp)? == 0 {
            return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "eof"));
        }
        parse(&resp)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }
}

/// Sends with the documented client recovery loop: reconnect on injected
/// connection drops, back off and retry on typed retryable errors.
/// Panics when a failure response is malformed (missing `code` /
/// `retryable`) or a non-retryable error arrives — both acceptance
/// violations.
fn send_with_retry(addr: std::net::SocketAddr, client: &mut Client, req: &str) -> Json {
    for _ in 0..80 {
        let resp = match client.send(req) {
            Ok(r) => r,
            Err(_) => {
                std::thread::sleep(std::time::Duration::from_millis(2));
                *client = Client::connect(addr).expect("reconnect");
                continue;
            }
        };
        if resp.get("ok").and_then(Json::as_bool) == Some(true) {
            return resp;
        }
        let code = resp.get("code").and_then(Json::as_str);
        let retryable = resp.get("retryable").and_then(Json::as_bool);
        assert!(
            code.is_some() && retryable.is_some(),
            "malformed error response: {resp}"
        );
        assert_eq!(retryable, Some(true), "non-retryable failure for {req}: {resp}");
        let backoff = resp
            .get("retry_after_ms")
            .and_then(Json::as_usize)
            .unwrap_or(2) as u64;
        std::thread::sleep(std::time::Duration::from_millis(backoff.clamp(1, 100)));
    }
    panic!("request never recovered: {req}");
}

/// The wire request for workload variant `v` (cycled per client). The
/// two `sf` lambdas share one balanced-separator structure, so the
/// second spec exercises the structure-store hit path (and its `corrupt`
/// rule). Fields are formatted with `{}` — the shortest exact f64 form —
/// so the oracle engine sees bitwise-identical inputs.
fn request_for(v: usize, cloud: usize, field: &[f64]) -> String {
    let fj = field.iter().map(|x| format!("{x}")).collect::<Vec<_>>().join(",");
    match v % 6 {
        0 => format!(
            r#"{{"op":"integrate","cloud":{cloud},"backend":"sf","field":[{fj}],"d":1,"lambda":2.0,"threshold":16}}"#
        ),
        1 => format!(
            r#"{{"op":"integrate","cloud":{cloud},"backend":"sf","field":[{fj}],"d":1,"lambda":4.0,"threshold":16}}"#
        ),
        2 => format!(
            r#"{{"op":"integrate","cloud":{cloud},"backend":"rfd","field":[{fj}],"d":1,"m":8}}"#
        ),
        3 => format!(
            r#"{{"op":"integrate","cloud":{cloud},"backend":"bf_sp","field":[{fj}],"d":1,"lambda":2.0}}"#
        ),
        4 => format!(
            r#"{{"op":"integrate","cloud":{cloud},"backend":"bf_diffusion","field":[{fj}],"d":1,"epsilon":0.25,"lambda":-0.2}}"#
        ),
        _ => format!(
            r#"{{"op":"integrate","cloud":{cloud},"backend":"trees_bartal","field":[{fj}],"d":1,"count":3,"lambda":2.0,"seed":1}}"#
        ),
    }
}

/// The acceptance chaos run: a seeded plan worth 25+ fault fires
/// (panics, spurious errors, slow stages, artifact corruption,
/// connection drops) across five backends plus the server accept/read
/// path, absorbed by two concurrent retrying clients.
#[test]
fn chaos_plan_recovers_to_bitwise_identical_results() {
    const PLAN: &str = "seed=11;\
        site=prepare,backend=sf,kind=panic,times=3;\
        site=finish,backend=sf,kind=error,times=3;\
        site=prepare,backend=rfd,kind=panic,times=3;\
        site=apply,backend=rfd,kind=panic,times=3;\
        site=apply,backend=bf_sp,kind=delay,ms=2,times=4;\
        site=prepare,backend=bf_diffusion,kind=error,times=3;\
        site=apply,backend=trees,kind=panic,times=2;\
        site=structure_hit,backend=sf,kind=corrupt,times=2;\
        site=accept,kind=drop,times=2;\
        site=read,kind=drop,times=2,every=4";
    let plan = FaultPlan::parse(PLAN).unwrap();
    assert!(plan.rules.iter().map(|r| r.times).sum::<u64>() >= 20);

    // Unfaulted oracle: same mesh (register_mesh is deterministic), same
    // specs, same fields.
    let clean = Arc::new(EngineConfig::default().fault_plan(FaultPlan::default()).build());
    let clean_id = clean.register_mesh(gfi::mesh::icosphere(1), "chaos");
    let n = clean.cloud(clean_id).unwrap().scene.len();

    let engine = Arc::new(
        EngineConfig::default()
            .fault_plan(plan)
            .quarantine_attempts(10) // deeper than any rule's panic budget
            .quarantine_backoff_ms(1)
            .build(),
    );
    let (addr, server_thread) = spawn_server(engine.clone(), server::ServerConfig::default());

    let mut ctl = Client::connect(addr).unwrap();
    let reg = send_with_retry(
        addr,
        &mut ctl,
        r#"{"op":"register_mesh","kind":"icosphere","param":1,"name":"chaos"}"#,
    );
    let cloud = reg.get("id").unwrap().as_usize().unwrap();

    std::thread::scope(|s| {
        let clean = &clean;
        for cid in 0..2usize {
            s.spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                let mut rng = Rng::new(cid as u64 + 500);
                for r in 0..12usize {
                    let field: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
                    let req = request_for(r, cloud, &field);
                    let resp = send_with_retry(addr, &mut client, &req);
                    let got = resp.get("result").unwrap().as_f64_vec().unwrap();
                    let spec =
                        IntegratorSpec::from_request(&parse(&req).unwrap()).unwrap();
                    let f = Mat::from_vec(n, 1, field);
                    let (want, _) = clean.integrate(clean_id, &spec, &f).unwrap();
                    assert_eq!(
                        got, want.data,
                        "variant {r} diverged from the unfaulted engine"
                    );
                }
            });
        }
    });

    // The same server must still answer (no worker thread died), the plan
    // must actually have fired, and every quarantined key must have
    // recovered — its last rebuild succeeded and cleared the record.
    let health = send_with_retry(addr, &mut ctl, r#"{"op":"health"}"#);
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"), "{health}");
    let rb = health.get("robustness").unwrap();
    assert_eq!(rb.get("quarantined_live").unwrap().as_usize(), Some(0));
    assert!(rb.get("quarantines").unwrap().as_usize().unwrap() >= 1);
    let injected = engine.faults().injected();
    assert!(injected >= 20, "plan injected only {injected} faults");
    assert!(engine.robustness_stats().panics_caught >= 8, "panic rules under-fired");

    let stats = send_with_retry(addr, &mut ctl, r#"{"op":"stats"}"#);
    let backlog = stats
        .get("server")
        .unwrap()
        .get("worker_backlog")
        .unwrap()
        .as_usize()
        .unwrap();
    assert!(backlog <= 4, "worker threads accumulated under chaos: {backlog}");

    send_with_retry(addr, &mut ctl, r#"{"op":"shutdown"}"#);
    drop(ctl); // free the last worker so the accept loop can join it
    server_thread.join().unwrap();
}

/// A key that keeps failing past `max_attempts` is *hard* quarantined —
/// typed error with no retry hint, waiting doesn't help — until the next
/// epoch (a good `update_cloud` frame) sweeps it and serving recovers.
#[test]
fn hard_quarantine_recovers_at_the_next_epoch() {
    let plan = FaultPlan::parse("site=prepare,backend=rfd,kind=panic,times=3").unwrap();
    let eng = EngineConfig::default()
        .fault_plan(plan)
        .quarantine_attempts(2)
        .quarantine_backoff_ms(0)
        .build();
    let raw = {
        let mut rng = Rng::new(3);
        gfi::pointcloud::random_cloud(40, &mut rng)
    };
    let id = eng.register_cloud(raw.clone(), "scan");
    let spec =
        IntegratorSpec::from_request(&parse(r#"{"backend":"rfd","m":8}"#).unwrap()).unwrap();
    let mut rng = Rng::new(77);
    let field = Mat::from_vec(40, 1, (0..40).map(|_| rng.gaussian()).collect());

    // Two injected panics reach max_attempts=2 → hard quarantine: the
    // third request is refused *without* consuming the remaining planned
    // fault, and waiting does not lift it.
    for _ in 0..2 {
        let err = eng.integrate(id, &spec, &field).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<GfiError>(),
            Some(GfiError::Internal { .. })
        ));
    }
    std::thread::sleep(std::time::Duration::from_millis(5));
    let err = eng.integrate(id, &spec, &field).unwrap_err();
    match err.downcast_ref::<GfiError>() {
        Some(GfiError::Quarantined { failures: 2, retry_after_ms: None, .. }) => {}
        other => panic!("expected hard quarantine, got {other:?}"),
    }
    assert_eq!(eng.faults().injected(), 2, "hard quarantine must gate the rebuild");

    // A good frame bumps the epoch and sweeps the record. The planned
    // fault has one fire left: it burns on the first post-sweep rebuild,
    // and the retry after it serves — bitwise-identical to a clean engine
    // fed the same registration + frame.
    let mut moved = raw;
    moved.points[5][1] += 0.01;
    eng.update_cloud(id, moved.clone(), &UpdateOpts::default()).unwrap();
    let err = eng.integrate(id, &spec, &field).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<GfiError>(),
        Some(GfiError::Internal { .. })
    ));
    let (out, _) = eng.integrate(id, &spec, &field).unwrap();
    assert_eq!(eng.robustness_stats().quarantined_live, 0);

    let clean = EngineConfig::default().fault_plan(FaultPlan::default()).build();
    let cid = clean.register_cloud(
        {
            let mut rng = Rng::new(3);
            gfi::pointcloud::random_cloud(40, &mut rng)
        },
        "scan",
    );
    clean.update_cloud(cid, moved, &UpdateOpts::default()).unwrap();
    let (want, _) = clean.integrate(cid, &spec, &field).unwrap();
    assert_eq!(out.data, want.data, "post-recovery result diverged");
}

/// `max_inflight_prepares: 0` sheds every cache-miss prepare with the
/// typed `overloaded` error and its retry hint — and shedding is pure
/// backpressure: it never quarantines the refused key.
#[test]
fn zero_inflight_budget_sheds_all_prepares_with_typed_errors() {
    let eng = EngineConfig::default()
        .fault_plan(FaultPlan::default())
        .max_inflight_prepares(0)
        .build();
    let id = eng.register_mesh(gfi::mesh::icosphere(1), "s");
    let n = eng.cloud(id).unwrap().scene.len();
    let field = Mat::from_vec(n, 1, vec![1.0; n]);
    let spec =
        IntegratorSpec::from_request(&parse(r#"{"backend":"sf","lambda":2.0}"#).unwrap())
            .unwrap();
    assert!(eng.is_shedding());
    for _ in 0..3 {
        let err = eng
            .integrate_opts(id, &spec, &field, &RequestOpts::default())
            .unwrap_err();
        match err.downcast_ref::<GfiError>() {
            Some(GfiError::Overloaded { retry_after_ms, .. }) => assert!(*retry_after_ms > 0),
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }
    assert_eq!(eng.robustness_stats().sheds, 3);
    assert_eq!(eng.robustness_stats().quarantined_live, 0, "sheds must not quarantine");
}
