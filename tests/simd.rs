//! Differential-oracle suite for the explicit SIMD microkernels
//! (`rust/src/util/simd.rs` documents the dispatch ladder and the oracle
//! contract). Every test runs the same computation twice — dispatch
//! pinned to the scalar oracle, then to the native (AVX2/NEON) path —
//! and asserts the results are **bitwise identical**: the SIMD kernels
//! keep multiplies and adds separate (no FMA contraction) and preserve
//! the scalar association order, so exact equality is the contract, not
//! just ≤1 ULP. On hardware without the vector extensions both runs
//! resolve to the scalar kernel and the assertions hold trivially.
//!
//! CI additionally runs the *whole* test suite under `GFI_SIMD=off`, so
//! the scalar oracle itself stays exercised end to end.

use gfi::graph::{distances, CsrGraph};
use gfi::integrators::artifacts;
use gfi::integrators::rfd::RfdConfig;
use gfi::integrators::{prepare, IntegratorSpec, KernelFn, Precision, Scene};
use gfi::linalg::{gemm_naive, Mat, Trans};
use gfi::pointcloud::PointCloud;
use gfi::util::rng::Rng;
use gfi::util::simd::{set_override, SimdMode};
use std::sync::Mutex;

/// The dispatch override is process-global (one latch for every kernel),
/// so tests that pin it must serialize — `cargo test` runs integration
/// tests on a thread pool.
static LOCK: Mutex<()> = Mutex::new(());

/// Runs `f` once under the pinned scalar oracle and once under native
/// dispatch, releasing the override afterwards even on panic-free exit.
/// Returns `(scalar, native)` for the caller to compare.
fn differential<T>(f: impl Fn() -> T) -> (T, T) {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    set_override(Some(SimdMode::Scalar));
    let scalar = f();
    set_override(Some(SimdMode::Native));
    let native = f();
    set_override(None);
    (scalar, native)
}

fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_vec(rows, cols, (0..rows * cols).map(|_| rng.gaussian()).collect())
}

fn bits(m: &Mat) -> Vec<u64> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

// ---------------------------------------------------------------------
// GEMM: the MR×NR microkernel vs its scalar oracle
// ---------------------------------------------------------------------

/// Adversarial shapes around every boundary in the blocked GEMM: empty
/// operands, 1×1, the small-flops reference path, exact MR/NR multiples,
/// MR/NR remainders, multiple row blocks (MC = 64), multiple depth
/// panels (KC = 256, the tall-k split path) — under assorted α/β
/// combinations on a dirty (non-zero) C.
#[test]
fn gemm_simd_is_bitwise_equal_to_scalar_across_shapes() {
    // (n, k, m): op(A) is n×k, op(B) is k×m.
    let shapes = [
        (0usize, 3usize, 2usize), // empty output rows
        (3, 0, 2),                // k = 0: pure C ← β·C
        (1, 1, 1),
        (5, 7, 3),    // small-flops reference path
        (40, 40, 40), // exercises the blocked path (64000 flops)
        (36, 41, 48), // exact MR multiple × NR multiple
        (37, 41, 13), // MR remainder 1, NR remainder 5
        (39, 35, 47), // MR remainder 3, NR remainder 7
        (130, 19, 33), // three MC row blocks
        (9, 600, 17), // one row block, three KC panels: tall-k split
    ];
    let alphas_betas = [(1.0, 0.0), (1.0, 1.0), (0.5, 0.25), (-1.25, 1.0), (0.0, 0.75)];
    for (si, &(n, k, m)) in shapes.iter().enumerate() {
        for (ci, &(alpha, beta)) in alphas_betas.iter().enumerate() {
            for (ta, tb) in [
                (Trans::No, Trans::No),
                (Trans::Yes, Trans::No),
                (Trans::No, Trans::Yes),
                (Trans::Yes, Trans::Yes),
            ] {
                let seed = (si * 100 + ci) as u64;
                let (ar, ac) = if matches!(ta, Trans::No) { (n, k) } else { (k, n) };
                let (br, bc) = if matches!(tb, Trans::No) { (k, m) } else { (m, k) };
                let a = rand_mat(ar, ac, seed);
                let b = rand_mat(br, bc, seed + 1);
                let c0 = rand_mat(n, m, seed + 2);
                let run = || {
                    let mut c = c0.clone();
                    c.gemm_assign(alpha, &a, ta, &b, tb, beta);
                    c
                };
                let (scalar, native) = differential(run);
                assert_eq!(
                    bits(&scalar),
                    bits(&native),
                    "gemm {n}x{k}x{m} ta={ta:?} tb={tb:?} alpha={alpha} beta={beta}"
                );
                // And the blocked result matches the naive triple-loop
                // oracle to high accuracy (association differs, so not
                // bitwise).
                let mut naive = c0.clone();
                gemm_naive(alpha, &a, ta, &b, tb, beta, &mut naive);
                for (x, y) in scalar.data.iter().zip(naive.data.iter()) {
                    assert!((x - y).abs() <= 1e-10 * (1.0 + y.abs()));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Kernel-table evaluation (sp_kernel_from_distances / sp_kernel_map)
// ---------------------------------------------------------------------

/// Every kernel profile over a distance table salted with ∞ (unreachable
/// pairs) and huge-but-finite entries: the vectorized rows must match the
/// scalar evaluation bitwise, including the unreachable → 0 convention.
#[test]
fn kernel_tables_simd_match_scalar_bitwise() {
    let n = 67; // NR-odd size: exercises vector body + remainder lanes
    let mut rng = Rng::new(41);
    let mut dist = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            dist[(i, j)] = match rng.below(12) {
                0 => f64::INFINITY,
                1 => 1e300,
                _ => rng.uniform_in(0.0, 8.0),
            };
        }
    }
    let kernels = [
        KernelFn::ExpNeg(0.7),
        KernelFn::GaussianSq(0.3),
        KernelFn::Rational(1.9),
        KernelFn::DampedSine { a: 1.1, b: 0.4, omega: 3.0, phi: 0.2 },
        KernelFn::custom("halve", |x| if x.is_finite() { 0.5 * x } else { 0.0 }),
    ];
    for f in &kernels {
        let (s, v) = differential(|| artifacts::sp_kernel_map(&dist, f));
        assert_eq!(bits(&s), bits(&v), "sp_kernel_map {f:?}");
        let (s, v) = differential(|| artifacts::sp_kernel_from_distances(dist.clone(), f));
        assert_eq!(bits(&s), bits(&v), "sp_kernel_from_distances {f:?}");
        // The f32 table derives from the same scalar evaluations in both
        // modes (quantization is elementwise), so it must agree too.
        let d32 = artifacts::distances_to_f32(&dist);
        let (s, v) = differential(|| artifacts::sp_kernel_map_f32(&d32, f));
        assert_eq!(
            s.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            v.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "sp_kernel_map_f32 {f:?}"
        );
    }
}

// ---------------------------------------------------------------------
// Dijkstra relaxation (the AVX2 gather prefilter)
// ---------------------------------------------------------------------

/// Two random components (so unreachable = ∞ flows through the gather
/// compare) plus high-degree hubs (so edge chunks of ≥ 4 exist): full
/// distance matrices and nearest-source assignments must be bitwise
/// identical between dispatch modes.
#[test]
fn dijkstra_simd_prefilter_is_bitwise_equal_to_scalar() {
    for seed in 0..6u64 {
        let n = 140;
        let cut = 90; // nodes ≥ cut form a disconnected component
        let mut rng = Rng::new(1000 + seed);
        let mut edges = Vec::new();
        for i in 0..n - 1 {
            if i + 1 == cut {
                continue;
            }
            edges.push((i, i + 1, rng.uniform_in(0.1, 2.0)));
        }
        // Random intra-component chords, including hub fan-out so many
        // vertices relax ≥ 4 edges per pop.
        for _ in 0..4 * n {
            let (lo, hi) = if rng.below(2) == 0 { (0, cut) } else { (cut, n) };
            let a = lo + rng.below(hi - lo);
            let b = lo + rng.below(hi - lo);
            if a != b {
                edges.push((a, b, rng.uniform_in(0.05, 3.0)));
            }
        }
        let g = CsrGraph::from_edges(n, &edges);
        let sources: Vec<usize> = vec![0, 3, 17 % cut];
        let (s, v) = differential(|| distances::distance_matrix(&g, &sources));
        assert_eq!(bits(&s), bits(&v), "distance_matrix seed={seed}");
        // Unreachable pairs must be ∞ in both (sources are all < cut).
        assert!(s.data.iter().any(|d| *d == f64::INFINITY));
        let (sa, va) = differential(|| distances::nearest_sources(&g, &sources));
        assert_eq!(
            sa.0.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            va.0.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            "nearest_sources dist seed={seed}"
        );
        assert_eq!(sa.1, va.1, "nearest_sources assignment seed={seed}");
    }
}

// ---------------------------------------------------------------------
// End-to-end integrators (fill_features + GEMM + apply hot paths)
// ---------------------------------------------------------------------

fn cloud_scene(n: usize, seed: u64) -> Scene {
    let mut rng = Rng::new(seed);
    let pts: Vec<[f64; 3]> =
        (0..n).map(|_| [rng.uniform(), rng.uniform(), rng.uniform()]).collect();
    Scene::from_points(PointCloud::new(pts))
}

/// RFD prepare + apply — the random-feature fill (gathered phase dot
/// products), the Gram/Woodbury GEMMs, and the three-stage apply — must
/// be bitwise reproducible across dispatch modes, in every precision
/// policy.
#[test]
fn rfd_pipeline_is_bitwise_equal_across_dispatch_modes() {
    let scene = cloud_scene(90, 5);
    let field = rand_mat(90, 3, 6);
    let base = IntegratorSpec::Rfd(RfdConfig { num_features: 12, ..Default::default() });
    for spec in [
        base.clone(),
        IntegratorSpec::with_precision(Precision::F32, base.clone()),
        IntegratorSpec::with_precision(Precision::F32AccF64, base),
    ] {
        let (s, v) = differential(|| {
            let integ = prepare(&scene, &spec).expect("prepare");
            integ.apply(&field)
        });
        assert_eq!(bits(&s), bits(&v), "{spec:?}");
    }
}

/// BF-sp (dense kernel table from batched Dijkstra) end-to-end, f64 and
/// both f32 policies.
#[test]
fn bf_sp_pipeline_is_bitwise_equal_across_dispatch_modes() {
    let mut mesh = gfi::mesh::icosphere(1);
    mesh.normalize_unit_box();
    let scene = Scene::from_mesh(&mesh);
    let n = scene.len();
    let field = rand_mat(n, 2, 9);
    let base = IntegratorSpec::BfSp(KernelFn::ExpNeg(1.3));
    for spec in [
        base.clone(),
        IntegratorSpec::with_precision(Precision::F32, base.clone()),
        IntegratorSpec::with_precision(Precision::F32AccF64, base),
    ] {
        let (s, v) = differential(|| {
            let integ = prepare(&scene, &spec).expect("prepare");
            integ.apply(&field)
        });
        assert_eq!(bits(&s), bits(&v), "{spec:?}");
    }
}
