//! Event-driven front-end acceptance suite (docs/ARCHITECTURE.md,
//! "Event-driven serving"; docs/PROTOCOL.md, "Binary framing").
//!
//! Covers the properties the evented server must hold over real
//! sockets: pipelined bursts answered strictly in request order,
//! framing robustness under adversarial bytes (partial frames, mid-frame
//! disconnects, oversized lengths, checksum flips — typed errors, never
//! panics or hangs), line-JSON compat on the same port, bitwise result
//! parity with the blocking thread-per-connection server, and the
//! cross-connection micro-batching acceptance test: same-`(cloud, spec)`
//! requests from distinct connections provably coalesce into ONE
//! `integrate_batch` engine call.

#![cfg(unix)]

use gfi::coordinator::evented::serve_evented_with;
use gfi::coordinator::frame::{self, opcode};
use gfi::coordinator::server::{serve_with, ServerConfig};
use gfi::coordinator::Engine;
use gfi::util::json::{parse, Json};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{mpsc, Arc};
use std::time::Duration;

fn spawn_evented(
    engine: Arc<Engine>,
    cfg: ServerConfig,
) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        serve_evented_with(engine, "127.0.0.1:0", cfg, move |a| tx.send(a).unwrap())
            .unwrap();
    });
    (rx.recv().unwrap(), h)
}

fn spawn_threaded(
    engine: Arc<Engine>,
    cfg: ServerConfig,
) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::spawn(move || {
        serve_with(engine, "127.0.0.1:0", cfg, move |a| tx.send(a).unwrap()).unwrap();
    });
    (rx.recv().unwrap(), h)
}

/// Minimal binary-transport client: buffers socket reads and yields
/// response frames strictly in arrival order (the ordering assert rides
/// on that).
struct BinClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl BinClient {
    fn connect(addr: SocketAddr) -> Self {
        BinClient { stream: TcpStream::connect(addr).unwrap(), buf: Vec::new() }
    }

    fn send(&mut self, op: u8, id: u64, payload: &str) {
        self.stream
            .write_all(&frame::encode(op, id, payload.as_bytes()))
            .unwrap();
    }

    /// Next response frame, in wire order.
    fn recv(&mut self) -> (u8, u64, Json) {
        let mut chunk = [0u8; 64 * 1024];
        loop {
            if let Some((f, used)) =
                frame::decode(&self.buf).expect("response frames are well-formed")
            {
                self.buf.drain(..used);
                let body = String::from_utf8(f.payload).unwrap();
                return (f.op, f.id, parse(&body).unwrap());
            }
            let n = self.stream.read(&mut chunk).unwrap();
            assert!(n > 0, "server closed with a response still pending");
            self.buf.extend_from_slice(&chunk[..n]);
        }
    }

    fn roundtrip(&mut self, op: u8, id: u64, payload: &str) -> Json {
        self.send(op, id, payload);
        let (rop, rid, resp) = self.recv();
        assert_eq!((rop, rid), (op, id), "response echoes the request frame header");
        resp
    }
}

/// Reads to EOF and asserts the stream held exactly one framing-error
/// frame (op 0, id 0 — the offending header is untrusted) and nothing
/// after it. Returns the decoded error payload.
fn read_frame_error_then_eof(stream: &mut TcpStream) -> Json {
    let mut all = Vec::new();
    stream.read_to_end(&mut all).unwrap();
    let (f, used) = frame::decode(&all)
        .expect("error frame is well-formed")
        .expect("one error frame precedes the close");
    assert_eq!(used, all.len(), "nothing may follow the framing-error frame");
    assert_eq!((f.op, f.id), (0, 0));
    parse(&String::from_utf8(f.payload).unwrap()).unwrap()
}

fn integrate_payload(cloud: u64, field: &[f64]) -> String {
    let flat: Vec<String> = field.iter().map(|x| format!("{x}")).collect();
    format!(
        r#"{{"cloud":{cloud},"backend":"rfd","field":[{}],"d":1,"m":8,"seed":3}}"#,
        flat.join(",")
    )
}

fn result_f64s(resp: &Json) -> Vec<f64> {
    resp.get("result")
        .and_then(Json::as_f64_vec)
        .unwrap_or_else(|| panic!("no result array in {resp}"))
}

/// Bitwise equality — the serving stack's parity bar. The in-tree JSON
/// serializer prints f64s in shortest-roundtrip form, so wire results
/// preserve exact bit patterns.
fn assert_bitwise(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i} differs: {x} vs {y}"
        );
    }
}

#[test]
fn pipelined_burst_is_answered_in_request_order() {
    let engine = Arc::new(Engine::new(None));
    let (addr, server) = spawn_evented(engine.clone(), ServerConfig::default());
    let mut c = BinClient::connect(addr);
    let r = c.roundtrip(
        opcode::REGISTER_MESH,
        1,
        r#"{"kind":"icosphere","param":1}"#,
    );
    assert_eq!(r.get("n").unwrap().as_usize(), Some(42));
    let n = 42;

    // One write carrying 12 heavy integrates followed by 4 instant
    // healths. Workers finish the healths first; the connection must
    // still see responses strictly in request order, each echoing its id.
    let mut burst = Vec::new();
    let mut expected_ids = Vec::new();
    let mut fields: Vec<Vec<f64>> = Vec::new();
    for i in 0..12u64 {
        let field: Vec<f64> = (0..n).map(|j| (i as f64 + 1.0) * 0.1 + j as f64).collect();
        burst.extend_from_slice(&frame::encode(
            opcode::INTEGRATE,
            100 + i,
            integrate_payload(1, &field).as_bytes(),
        ));
        expected_ids.push(100 + i);
        fields.push(field);
    }
    for i in 0..4u64 {
        burst.extend_from_slice(&frame::encode(opcode::HEALTH, 200 + i, b"{}"));
        expected_ids.push(200 + i);
    }
    c.stream.write_all(&burst).unwrap();

    let spec = gfi::integrators::IntegratorSpec::Rfd(gfi::integrators::rfd::RfdConfig {
        num_features: 8,
        seed: 3,
        ..Default::default()
    });
    for (k, want_id) in expected_ids.iter().enumerate() {
        let (_, id, resp) = c.recv();
        assert_eq!(id, *want_id, "response {k} out of order");
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        if k < fields.len() {
            // Pipelined (and possibly coalesced) results are bitwise what
            // a direct engine call produces.
            let f = gfi::linalg::Mat::from_vec(n, 1, fields[k].clone());
            let want = engine.integrate(1, &spec, &f).unwrap().0;
            assert_bitwise(&result_f64s(&resp), &want.data, "pipelined integrate");
        }
    }
    c.roundtrip(opcode::SHUTDOWN, 999, "{}");
    server.join().unwrap();
}

#[test]
fn partial_frames_and_split_writes_reassemble() {
    let engine = Arc::new(Engine::new(None));
    let (addr, server) = spawn_evented(engine, ServerConfig::default());
    let mut c = BinClient::connect(addr);

    // Dribble one frame across several writes with pauses: the server
    // must wait for the remainder, not error or time out.
    let bytes = frame::encode(opcode::REGISTER_MESH, 7, br#"{"kind":"grid","param":4}"#);
    for piece in bytes.chunks(5) {
        c.stream.write_all(piece).unwrap();
        c.stream.flush().unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    let (op, id, resp) = c.recv();
    assert_eq!((op, id), (opcode::REGISTER_MESH, 7));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");

    // A second request on the now-established binary connection works.
    let r = c.roundtrip(opcode::HEALTH, 8, "{}");
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    c.roundtrip(opcode::SHUTDOWN, 9, "{}");
    server.join().unwrap();
}

#[test]
fn mid_frame_disconnect_does_not_wedge_the_server() {
    let engine = Arc::new(Engine::new(None));
    let (addr, server) = spawn_evented(engine, ServerConfig::default());

    // A client starts a frame, sends half the header, and vanishes.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let bytes = frame::encode(opcode::STATS, 1, b"{}");
        s.write_all(&bytes[..8]).unwrap();
        s.flush().unwrap();
    } // dropped: RST/FIN mid-frame

    // And another half-writes a *pipelined* second frame after a valid
    // first one, then vanishes — the first request may or may not have
    // been answered by then; the server must simply carry on.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut bytes = frame::encode(opcode::HEALTH, 2, b"{}");
        let second = frame::encode(opcode::STATS, 3, b"{}");
        bytes.extend_from_slice(&second[..second.len() / 2]);
        s.write_all(&bytes).unwrap();
        s.flush().unwrap();
    }

    // The server still serves fresh connections on both transports.
    let mut c = BinClient::connect(addr);
    let r = c.roundtrip(opcode::HEALTH, 4, "{}");
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    c.roundtrip(opcode::SHUTDOWN, 5, "{}");
    server.join().unwrap();
}

#[test]
fn oversized_length_prefix_gets_typed_error_and_close() {
    let engine = Arc::new(Engine::new(None));
    let (addr, server) = spawn_evented(engine, ServerConfig::default());
    let mut s = TcpStream::connect(addr).unwrap();

    // A syntactically valid header whose length prefix exceeds the 64 MiB
    // cap: the server must refuse before allocating anything near it.
    let mut bytes = frame::encode(opcode::INTEGRATE, 11, b"{}");
    let huge = (frame::MAX_PAYLOAD as u32) + 1;
    bytes[11..15].copy_from_slice(&huge.to_le_bytes());
    s.write_all(&bytes).unwrap();

    let err = read_frame_error_then_eof(&mut s);
    assert_eq!(err.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(err.get("code").and_then(Json::as_str), Some("frame_too_large"), "{err}");
    assert_eq!(err.get("retryable"), Some(&Json::Bool(false)));

    // The server itself is unharmed.
    let mut c = BinClient::connect(addr);
    c.roundtrip(opcode::SHUTDOWN, 1, "{}");
    server.join().unwrap();
}

#[test]
fn corrupted_frames_get_typed_errors_and_close() {
    let engine = Arc::new(Engine::new(None));
    let (addr, server) = spawn_evented(engine, ServerConfig::default());

    // Checksum flip: valid frame, last trailer byte xored.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut bytes = frame::encode(opcode::HEALTH, 21, b"{}");
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        s.write_all(&bytes).unwrap();
        let err = read_frame_error_then_eof(&mut s);
        assert_eq!(
            err.get("code").and_then(Json::as_str),
            Some("bad_frame_checksum"),
            "{err}"
        );
    }

    // Bad version byte on a fresh binary connection.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut bytes = frame::encode(opcode::HEALTH, 22, b"{}");
        bytes[1] = 99;
        s.write_all(&bytes).unwrap();
        let err = read_frame_error_then_eof(&mut s);
        assert_eq!(
            err.get("code").and_then(Json::as_str),
            Some("bad_frame_version"),
            "{err}"
        );
    }

    // Garbage after a valid frame: binary mode is locked in, so the
    // stray byte is a framing error (bad magic), answered after the
    // valid request and followed by a close.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut bytes = frame::encode(opcode::HEALTH, 23, b"{}");
        bytes.push(b'x');
        s.write_all(&bytes).unwrap();
        let mut all = Vec::new();
        s.read_to_end(&mut all).unwrap();
        let (first, used) = frame::decode(&all).unwrap().expect("health response first");
        assert_eq!(first.id, 23);
        let health = parse(&String::from_utf8(first.payload).unwrap()).unwrap();
        assert_eq!(health.get("ok"), Some(&Json::Bool(true)), "{health}");
        let (errf, used2) = frame::decode(&all[used..])
            .unwrap()
            .expect("then the framing error");
        assert_eq!(used + used2, all.len());
        let err = parse(&String::from_utf8(errf.payload).unwrap()).unwrap();
        assert_eq!(
            err.get("code").and_then(Json::as_str),
            Some("bad_frame_magic"),
            "{err}"
        );
    }

    let mut c = BinClient::connect(addr);
    c.roundtrip(opcode::SHUTDOWN, 1, "{}");
    server.join().unwrap();
}

#[test]
fn json_compat_serves_the_full_protocol_on_the_evented_server() {
    let engine = Arc::new(Engine::new(None));
    let (addr, server) = spawn_evented(engine, ServerConfig::default());
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut send = |stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, l: &str| {
        writeln!(stream, "{l}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        parse(&resp).unwrap()
    };
    let field: String = (0..42).map(|i| i.to_string()).collect::<Vec<_>>().join(",");

    let reg = send(&mut stream, &mut reader, r#"{"op":"register_mesh","kind":"icosphere","param":1}"#);
    assert_eq!(reg.get("n").unwrap().as_usize(), Some(42));
    let one = format!(
        r#"{{"op":"integrate","cloud":1,"backend":"rfd","field":[{field}],"d":1,"m":8}}"#
    );
    let r1 = send(&mut stream, &mut reader, &one);
    assert_eq!(r1.get("ok"), Some(&Json::Bool(true)), "{r1}");
    assert_eq!(r1.get("cache_hit"), Some(&Json::Bool(false)));
    let r2 = send(&mut stream, &mut reader, &one);
    assert_eq!(r2.get("cache_hit"), Some(&Json::Bool(true)));
    assert_bitwise(
        &result_f64s(&r1),
        &result_f64s(&r2),
        "cold vs warm over JSON compat",
    );

    // Errors stay errors, not disconnects.
    let bad = send(&mut stream, &mut reader, "not json");
    assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
    let evicted = send(&mut stream, &mut reader, r#"{"op":"evict","cloud":1,"backend":"rfd","m":8}"#);
    assert_eq!(evicted.get("evicted").unwrap().as_usize(), Some(1));
    let stats = send(&mut stream, &mut reader, r#"{"op":"stats"}"#);
    assert_eq!(stats.get("clouds").unwrap().as_usize(), Some(1));
    assert_eq!(
        stats.get("batcher").unwrap().get("enabled"),
        Some(&Json::Bool(true)),
        "evented stats carry the batcher block: {stats}"
    );
    let un = send(&mut stream, &mut reader, r#"{"op":"unregister_cloud","cloud":1}"#);
    assert_eq!(un.get("removed"), Some(&Json::Bool(true)));
    send(&mut stream, &mut reader, r#"{"op":"shutdown"}"#);
    server.join().unwrap();
}

#[test]
fn evented_results_are_bitwise_identical_to_the_blocking_server() {
    // Two engines from identical (deterministic, seeded) configs, one
    // behind each front-end: the same request must produce byte-for-byte
    // the same result array over blocking JSON, evented JSON compat, and
    // evented binary frames.
    let (t_addr, t_server) =
        spawn_threaded(Arc::new(Engine::new(None)), ServerConfig::default());
    let (e_addr, e_server) =
        spawn_evented(Arc::new(Engine::new(None)), ServerConfig::default());

    let reg = r#"{"op":"register_mesh","kind":"icosphere","param":1}"#;
    let field: String = (0..42).map(|i| format!("{}.25", i)).collect::<Vec<_>>().join(",");
    let line = format!(
        r#"{{"op":"integrate","cloud":1,"backend":"rfd","field":[{field}],"d":1,"m":8,"seed":3}}"#
    );

    let json_roundtrips = |addr: SocketAddr| -> Vec<f64> {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut send = |stream: &mut TcpStream, l: &str, reader: &mut BufReader<TcpStream>| {
            writeln!(stream, "{l}").unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            parse(&resp).unwrap()
        };
        send(&mut stream, reg, &mut reader);
        let r = send(&mut stream, &line, &mut reader);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        result_f64s(&r)
    };
    let threaded = json_roundtrips(t_addr);
    let evented_json = json_roundtrips(e_addr);

    let mut c = BinClient::connect(e_addr);
    let payload = format!(
        r#"{{"cloud":1,"backend":"rfd","field":[{field}],"d":1,"m":8,"seed":3}}"#
    );
    let r = c.roundtrip(opcode::INTEGRATE, 77, &payload);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    let evented_binary = result_f64s(&r);

    assert_bitwise(&threaded, &evented_json, "blocking vs evented JSON");
    assert_bitwise(&threaded, &evented_binary, "blocking JSON vs evented binary");

    let mut stream = TcpStream::connect(t_addr).unwrap();
    writeln!(stream, r#"{{"op":"shutdown"}}"#).unwrap();
    let mut resp = String::new();
    BufReader::new(stream).read_line(&mut resp).unwrap();
    t_server.join().unwrap();
    c.roundtrip(opcode::SHUTDOWN, 78, "{}");
    e_server.join().unwrap();
}

#[test]
fn distinct_connections_coalesce_into_one_engine_batch_call() {
    // The tentpole acceptance test: two *separate connections* fire the
    // same (cloud, spec) integrate inside one batching window; the
    // batcher must execute them as ONE engine call, proven three ways —
    // the batcher counters, the per-backend metrics count (bumped once
    // per engine call), and results bitwise-identical to direct
    // unbatched engine calls.
    let engine = Arc::new(Engine::new(None));
    let (addr, server) = spawn_evented(
        engine.clone(),
        ServerConfig {
            // A wide window so both requests land in the same collection
            // round regardless of scheduling noise.
            batch_window_us: 300_000,
            workers: 4,
            ..Default::default()
        },
    );
    let mut admin = BinClient::connect(addr);
    admin.roundtrip(opcode::REGISTER_MESH, 1, r#"{"kind":"icosphere","param":1}"#);
    let n = 42usize;

    // Warm the prepared integrator outside the measured window so the
    // coalesced batch is pure apply work.
    let warm: Vec<f64> = (0..n).map(|j| j as f64).collect();
    let r = admin.roundtrip(opcode::INTEGRATE, 2, &integrate_payload(1, &warm));
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");

    let stats0 = admin.roundtrip(opcode::STATS, 3, "{}");
    let count0 = stats0
        .get("backends")
        .and_then(|b| b.get("rfd"))
        .and_then(|r| r.get("count"))
        .and_then(Json::as_usize)
        .unwrap();
    let b0 = stats0.get("batcher").unwrap().clone();
    let formed0 = b0.get("batches_formed").unwrap().as_usize().unwrap();
    let coalesced0 = b0.get("coalesced_requests").unwrap().as_usize().unwrap();

    // Two clients, two sockets, same (cloud, spec), different fields.
    let fields: Vec<Vec<f64>> = (0..2)
        .map(|i| (0..n).map(|j| (i * n + j) as f64 * 0.5 + 1.0).collect())
        .collect();
    let results: Vec<Vec<f64>> = std::thread::scope(|s| {
        let handles: Vec<_> = fields
            .iter()
            .enumerate()
            .map(|(i, f)| {
                s.spawn(move || {
                    let mut c = BinClient::connect(addr);
                    let r = c.roundtrip(
                        opcode::INTEGRATE,
                        10 + i as u64,
                        &integrate_payload(1, f),
                    );
                    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
                    result_f64s(&r)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let stats1 = admin.roundtrip(opcode::STATS, 4, "{}");
    let count1 = stats1
        .get("backends")
        .and_then(|b| b.get("rfd"))
        .and_then(|r| r.get("count"))
        .and_then(Json::as_usize)
        .unwrap();
    let b1 = stats1.get("batcher").unwrap().clone();
    let formed1 = b1.get("batches_formed").unwrap().as_usize().unwrap();
    let coalesced1 = b1.get("coalesced_requests").unwrap().as_usize().unwrap();

    assert_eq!(
        count1 - count0,
        1,
        "two cross-connection requests must reach the engine as ONE \
         integrate_batch call (metrics count went {count0} -> {count1})"
    );
    assert_eq!(formed1 - formed0, 1, "exactly one merged group formed");
    assert_eq!(coalesced1 - coalesced0, 2, "both requests rode the merged group");

    // Bitwise parity against direct, unbatched engine calls.
    let spec = gfi::integrators::IntegratorSpec::Rfd(gfi::integrators::rfd::RfdConfig {
        num_features: 8,
        seed: 3,
        ..Default::default()
    });
    for (f, got) in fields.iter().zip(&results) {
        let m = gfi::linalg::Mat::from_vec(n, 1, f.clone());
        let want = engine.integrate(1, &spec, &m).unwrap().0;
        assert_bitwise(got, &want.data, "coalesced vs direct");
    }

    admin.roundtrip(opcode::SHUTDOWN, 5, "{}");
    server.join().unwrap();
}
