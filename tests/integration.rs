//! Cross-module integration + randomized property tests (proptest-style:
//! seeded random instances sweeping structural parameters; the offline
//! build has no proptest crate, so cases are explicit seed loops).
//! Every integrator is built through the unified
//! `prepare(&Scene, &IntegratorSpec)` factory.

use gfi::integrators::rfd::RfdConfig;
use gfi::integrators::sf::SfConfig;
use gfi::integrators::{prepare, FieldIntegrator, IntegratorSpec, KernelFn, Scene};
use gfi::linalg::Mat;
use gfi::util::rng::Rng;
use gfi::util::stats::rel_err;

fn rand_field(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_vec(n, d, (0..n * d).map(|_| rng.gaussian()).collect())
}

fn mesh_scene(mut mesh: gfi::mesh::TriMesh) -> Scene {
    mesh.normalize_unit_box();
    Scene::from_mesh(&mesh)
}

/// Property: every integrator is a *linear* operator —
/// `apply(αx + βy) == α·apply(x) + β·apply(y)`.
#[test]
fn property_integrators_are_linear() {
    let scene = mesh_scene(gfi::mesh::icosphere(2));
    let n = scene.len();
    let integrators: Vec<Box<dyn FieldIntegrator>> = vec![
        prepare(
            &scene,
            &IntegratorSpec::Sf(SfConfig {
                kernel: KernelFn::ExpNeg(2.0),
                threshold: 64,
                ..Default::default()
            }),
        )
        .unwrap(),
        prepare(
            &scene,
            &IntegratorSpec::Rfd(RfdConfig { num_features: 16, ..Default::default() }),
        )
        .unwrap(),
        prepare(&scene, &IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0))).unwrap(),
    ];
    for seed in 0..5u64 {
        let x = rand_field(n, 2, seed);
        let y = rand_field(n, 2, seed + 100);
        let mut rng = Rng::new(seed + 200);
        let (a, b) = (rng.uniform_in(-2.0, 2.0), rng.uniform_in(-2.0, 2.0));
        let mut combo = x.scale(a);
        combo.axpy(b, &y);
        for integ in &integrators {
            let lhs = integ.apply(&combo);
            let mut rhs = integ.apply(&x).scale(a);
            rhs.axpy(b, &integ.apply(&y));
            let e = rel_err(&lhs.data, &rhs.data);
            assert!(e < 1e-9, "{} not linear: {e} (seed {seed})", integ.name());
        }
    }
}

/// Property: the implied kernel matrix is symmetric —
/// `⟨apply(x), y⟩ == ⟨x, apply(y)⟩`.
#[test]
fn property_kernel_symmetry() {
    let scene = mesh_scene(gfi::mesh::torus(14, 8, 1.0, 0.35));
    let n = scene.len();
    let integrators: Vec<Box<dyn FieldIntegrator>> = vec![
        prepare(&scene, &IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0))).unwrap(),
        prepare(
            &scene,
            &IntegratorSpec::Rfd(RfdConfig { num_features: 8, ..Default::default() }),
        )
        .unwrap(),
    ];
    for seed in 0..5u64 {
        let x = rand_field(n, 1, seed);
        let y = rand_field(n, 1, seed + 77);
        for integ in &integrators {
            let kx = integ.apply(&x);
            let ky = integ.apply(&y);
            let lhs: f64 = kx.data.iter().zip(&y.data).map(|(a, b)| a * b).sum();
            let rhs: f64 = x.data.iter().zip(&ky.data).map(|(a, b)| a * b).sum();
            let denom = lhs.abs().max(rhs.abs()).max(1e-12);
            assert!(
                ((lhs - rhs) / denom).abs() < 1e-8,
                "{} kernel not symmetric (seed {seed}): {lhs} vs {rhs}",
                integ.name()
            );
        }
    }
}

/// Property: SF error decreases (weakly) as the separator budget grows.
#[test]
fn property_sf_separator_budget_monotonic_ish() {
    let scene = mesh_scene(gfi::mesh::icosphere(2));
    let n = scene.len();
    let bf = prepare(&scene, &IntegratorSpec::BfSp(KernelFn::ExpNeg(2.0))).unwrap();
    let x = rand_field(n, 3, 5);
    let exact = bf.apply(&x);
    let err_at = |sep: usize| {
        let sf = prepare(
            &scene,
            &IntegratorSpec::Sf(SfConfig {
                kernel: KernelFn::ExpNeg(2.0),
                threshold: 32,
                separator_size: sep,
                seed: 11,
                ..Default::default()
            }),
        )
        .unwrap();
        rel_err(&sf.apply(&x).data, &exact.data)
    };
    let coarse = err_at(2);
    let fine = err_at(24);
    assert!(
        fine <= coarse * 1.5 + 0.02,
        "bigger separator should not be much worse: {fine} vs {coarse}"
    );
}

/// Property: random-graph SF never panics and stays finite across many
/// random graph shapes (failure-injection sweep).
#[test]
fn property_sf_robust_on_random_graphs() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed);
        let n = 30 + rng.below(120);
        // Random connected-ish graph: path backbone + random extra edges.
        let mut edges: Vec<(usize, usize, f64)> =
            (1..n).map(|i| (i - 1, i, rng.uniform_in(0.1, 2.0))).collect();
        for _ in 0..n {
            let a = rng.below(n);
            let b = rng.below(n);
            if a != b {
                edges.push((a, b, rng.uniform_in(0.1, 2.0)));
            }
        }
        let scene = Scene::from_graph(gfi::graph::CsrGraph::from_edges(n, &edges));
        let sf = prepare(
            &scene,
            &IntegratorSpec::Sf(SfConfig {
                kernel: KernelFn::ExpNeg(1.0),
                unit_size: 0.05,
                threshold: 16,
                separator_size: 4,
                seed,
            }),
        )
        .unwrap();
        let x = rand_field(n, 2, seed);
        let out = sf.apply(&x);
        assert!(out.data.iter().all(|v| v.is_finite()), "seed {seed}");
        // Sanity vs exact. Random (non-mesh) graphs are outside SF's
        // bounded-genus design envelope — the guard here is "not garbage",
        // not mesh-grade accuracy.
        let bf = prepare(&scene, &IntegratorSpec::BfSp(KernelFn::ExpNeg(1.0))).unwrap();
        let e = rel_err(&out.data, &bf.apply(&x).data);
        assert!(e < 0.9, "seed {seed}: rel err {e}");
    }
}

/// Property: RFD variance shrinks with the feature count (MSE(m=64) <
/// MSE(m=4) against the exact low-rank limit... measured against the
/// dense ε-graph diffusion).
#[test]
fn property_rfd_error_decreases_with_features() {
    let mut rng = Rng::new(9);
    let pc = gfi::pointcloud::random_cloud(80, &mut rng);
    let w = pc.dense_adjacency(0.25, gfi::pointcloud::Norm::LInf, true);
    let dense = gfi::integrators::bf::BruteForceDiffusion::from_dense(&w, 0.4);
    let scene = Scene::from_points(pc);
    let x = rand_field(80, 2, 10);
    let exact = dense.apply(&x);
    let err_at = |m: usize| {
        // Average over seeds to smooth RF noise.
        let mut acc = 0.0;
        for seed in 0..3 {
            let rfd = prepare(
                &scene,
                &IntegratorSpec::Rfd(RfdConfig {
                    num_features: m,
                    epsilon: 0.25,
                    lambda: 0.4,
                    seed,
                    ..Default::default()
                }),
            )
            .unwrap();
            acc += rel_err(&rfd.apply(&x).data, &exact.data);
        }
        acc / 3.0
    };
    let low = err_at(4);
    let high = err_at(128);
    assert!(high < low, "m=128 err {high} !< m=4 err {low}");
}

/// Integration: coordinator round-trip against a directly-built
/// integrator (cache coherence).
#[test]
fn integration_engine_matches_direct() {
    let engine = gfi::coordinator::Engine::new(None);
    let mut mesh = gfi::mesh::icosphere(2);
    mesh.normalize_unit_box();
    let id = engine.register_mesh(mesh.clone(), "m");
    let scene = Scene::from_mesh(&mesh);
    let n = scene.len();
    let x = rand_field(n, 3, 20);
    let spec = IntegratorSpec::Sf(SfConfig {
        kernel: KernelFn::ExpNeg(3.0),
        seed: 2,
        ..Default::default()
    });
    let direct = prepare(&scene, &spec).unwrap().apply(&x);
    let (via_engine, _) = engine.integrate(id, &spec, &x).unwrap();
    let e = rel_err(&via_engine.data, &direct.data);
    assert!(e < 1e-12, "engine route differs: {e}");
}

/// Integration: OT barycenter through two different FMs stays consistent.
#[test]
fn integration_barycenter_sf_close_to_bf() {
    let mut mesh = gfi::mesh::icosphere(2);
    mesh.normalize_unit_box();
    let scene = Scene::from_mesh(&mesh);
    let n = scene.len();
    let area = mesh.vertex_areas();
    let bf = prepare(&scene, &IntegratorSpec::BfSp(KernelFn::ExpNeg(8.0))).unwrap();
    let fm_bf = |x: &Mat| bf.apply(x);
    let mus = gfi::ot::concentrated_distributions(n, &[0, n / 2], &fm_bf);
    let cfg = gfi::ot::BarycenterConfig { max_iter: 25, ..Default::default() };
    let mu_bf =
        gfi::ot::wasserstein_barycenter(&mus, &area, &[0.5, 0.5], &fm_bf, &cfg);
    let sf = prepare(
        &scene,
        &IntegratorSpec::Sf(SfConfig { kernel: KernelFn::ExpNeg(8.0), ..Default::default() }),
    )
    .unwrap();
    let fm_sf = |x: &Mat| sf.apply(x);
    let mu_sf =
        gfi::ot::wasserstein_barycenter(&mus, &area, &[0.5, 0.5], &fm_sf, &cfg);
    let m = gfi::util::stats::mse(&mu_sf, &mu_bf);
    assert!(m < 1e-4, "barycenter MSE {m}");
}
