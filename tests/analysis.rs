//! Tier-1 self-scan: run the in-tree invariant analyzer
//! (`rust/src/analysis/`, surfaced as `gfi-analyze`) over this repo's
//! own tree and require a spotless report — zero findings AND zero
//! suppressions. The zero-suppression bar is deliberate: the moment a
//! rule needs a permanent carve-out, it belongs in the rule itself
//! (like the `util/simd.rs` global-state allowlist), not in an
//! ever-growing pile of inline waivers.

use gfi::analysis;
use std::path::Path;

fn scan() -> analysis::Report {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let ctx = analysis::scan_repo(root).expect("scan repo tree");
    analysis::run(&ctx).expect("suppression directives must be well-formed")
}

#[test]
fn repo_tree_has_zero_findings() {
    let report = scan();
    let dump: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(
        report.findings.is_empty(),
        "gfi-analyze found {} violation(s):\n{}",
        report.findings.len(),
        dump.join("\n")
    );
}

#[test]
fn repo_tree_has_zero_suppressions() {
    let report = scan();
    let waived: Vec<String> = report.suppressed.iter().map(|f| f.to_string()).collect();
    assert!(
        report.suppressed.is_empty(),
        "inline `gfi-analyze: allow(..)` waivers are banned in-tree \
         (encode permanent exceptions in the rule itself):\n{}",
        waived.join("\n")
    );
}

#[test]
fn scan_covers_the_whole_tree() {
    let report = scan();
    assert_eq!(report.rules_run, analysis::registry().len());
    assert_eq!(report.rules_run, 9, "rule registry drifted from the documented set");
    // Sanity floor: the tree has far more than 40 .rs files; a tiny
    // count means the walker silently lost a scan root.
    assert!(
        report.files_scanned >= 40,
        "only {} files scanned — scan roots broken?",
        report.files_scanned
    );
}
