//! OT/GW benchmarks: the Sinkhorn barycenter loop (Tables 2/3) and GW
//! iteration cost (Fig. 7) with dense vs RFD-injected structures.
//!
//! Writes `BENCH_ot_gw.json` so CI's perf trajectory tracks the OT/GW
//! path alongside `BENCH_integrators.json` / `BENCH_coordinator.json`.

use gfi::gw::{gw_solve, DenseStructure, GwConfig, LowRankStructure, StructureMatrix};
use gfi::integrators::rfd::RfdConfig;
use gfi::integrators::sf::SfConfig;
use gfi::integrators::{prepare, FieldIntegrator, IntegratorSpec, KernelFn, Scene};
use gfi::linalg::Mat;
use gfi::ot::{concentrated_distributions, wasserstein_barycenter, BarycenterConfig};
use gfi::pointcloud::random_cloud;
use gfi::util::bench::{write_json, Bench, BenchResult};
use gfi::util::rng::Rng;

fn main() {
    let bench = Bench::new().with_budget(3.0).with_max_iters(8).with_env_overrides();
    let mut results: Vec<BenchResult> = Vec::new();

    // Barycenter with SF vs RFD FMs on a sphere.
    let mut mesh = gfi::mesh::icosphere(3);
    mesh.normalize_unit_box();
    let scene = Scene::from_mesh(&mesh);
    let n = scene.len();
    let area = mesh.vertex_areas();
    let centers = [0, n / 3, 2 * n / 3];
    let cfg = BarycenterConfig { max_iter: 10, ..Default::default() };
    let sf: Box<dyn FieldIntegrator> = prepare(
        &scene,
        &IntegratorSpec::Sf(SfConfig { kernel: KernelFn::ExpNeg(8.0), ..Default::default() }),
    )
    .unwrap();
    let fm_sf = |x: &Mat| sf.apply(x);
    let mus = concentrated_distributions(n, &centers, &fm_sf);
    results.push(bench.run(&format!("barycenter/sf-fm/n={n}/10iter"), || {
        wasserstein_barycenter(&mus, &area, &[1.0 / 3.0; 3], &fm_sf, &cfg)
    }));
    let rfd = prepare(
        &scene,
        &IntegratorSpec::Rfd(RfdConfig {
            num_features: 30,
            epsilon: 0.05,
            lambda: 0.5,
            ..Default::default()
        }),
    )
    .unwrap();
    let fm_rfd = |x: &Mat| rfd.apply(x);
    results.push(bench.run(&format!("barycenter/rfd-fm/n={n}/10iter"), || {
        wasserstein_barycenter(&mus, &area, &[1.0 / 3.0; 3], &fm_rfd, &cfg)
    }));

    // GW solve, dense vs low-rank.
    let gw_n = 300;
    let mut rng = Rng::new(3);
    let pa = random_cloud(gw_n, &mut rng);
    let pb = random_cloud(gw_n, &mut rng);
    let p = vec![1.0 / gw_n as f64; gw_n];
    let gw_cfg = GwConfig { max_iter: 5, ..Default::default() };
    let da = DenseStructure::diffusion(&pa, 0.3, -0.2);
    let db = DenseStructure::diffusion(&pb, 0.3, -0.2);
    results.push(bench.run(&format!("gw/dense/n={gw_n}/5iter"), || {
        gw_solve(&da, &db, &p, &p, &gw_cfg)
    }));
    let rc = RfdConfig { num_features: 16, epsilon: 0.3, lambda: -0.2, seed: 1, ..Default::default() };
    let la = LowRankStructure::from_rfd(&pa, rc.clone());
    let lb = LowRankStructure::from_rfd(&pb, RfdConfig { seed: 2, ..rc });
    results.push(bench.run(&format!("gw/rfd-lowrank/n={gw_n}/5iter"), || {
        gw_solve(&la, &lb, &p, &p, &gw_cfg)
    }));
    // The Hadamard-square building block on its own.
    results.push(bench.run(&format!("gw/hadamard-sq/dense/n={gw_n}"), || {
        da.hadamard_sq_vec(&p)
    }));
    results.push(bench.run(&format!("gw/hadamard-sq/khatri-rao/n={gw_n}"), || {
        la.hadamard_sq_vec(&p)
    }));

    // Shared-structure GW prep: the shortest-path structure consumes the
    // same distance-matrix artifact family as BF-sp, so a second kernel
    // over the same graph only pays the evaluation, not the Dijkstra.
    {
        let g = mesh.to_graph();
        let dist = gfi::integrators::artifacts::graph_distance_matrix(&g);
        results.push(bench.run(&format!("gw/sp-structure/full/n={n}"), || {
            DenseStructure::shortest_path(&g, &KernelFn::ExpNeg(4.0))
        }));
        results.push(bench.run(&format!("gw/sp-structure/from-shared/n={n}"), || {
            DenseStructure::new(gfi::integrators::artifacts::sp_kernel_map(
                &dist,
                &KernelFn::ExpNeg(4.0),
            ))
        }));
    }

    write_json("BENCH_ot_gw.json", &results).expect("write BENCH_ot_gw.json");
}
