//! Coordinator + runtime benchmarks: request-path latency of the cached
//! integrator route (both the allocating `integrate` and the
//! allocation-free `integrate_into`), the PJRT artifact route (when
//! artifacts exist), batcher throughput, the bounded-cache churn path
//! (eviction + transparent re-prepare on every request), the two-stage
//! prepare pipeline (`engine/prepare_shared` — kernel sweep reusing one
//! shared separator tree — vs `engine/prepare_full`), and the
//! mesh-dynamics frame-update path (`update_cloud` + SF dirty-subtree
//! refresh vs dropping the artifacts and paying a full re-prepare), and
//! the persistent-store warm restart (`engine/cold_start_cold_dir` —
//! fresh engine, empty disk — vs `engine/cold_start_warm_dir` — fresh
//! engine, disk tier pre-populated by a previous engine's spills), and
//! the serving tier over real sockets (`serve/throughput-threaded` —
//! thread-per-connection JSON roundtrips — vs `serve/throughput-evented`
//! — pipelined binary frames into the event loop — plus
//! `serve/p99-evented`, the per-request tail latency under the same
//! 64-client pipelined load).
//!
//! Writes `BENCH_coordinator.json` so CI's perf trajectory tracks the
//! serving path alongside `BENCH_integrators.json`.

use gfi::coordinator::batcher::{Batcher, BatcherConfig};
use gfi::coordinator::{Engine, EngineConfig, UpdateOpts};
use gfi::integrators::rfd::RfdConfig;
use gfi::integrators::sf::SfConfig;
use gfi::integrators::{IntegratorSpec, Scene};
use gfi::linalg::Mat;
use gfi::pointcloud::PointCloud;
use gfi::util::bench::{write_json, Bench, BenchResult};
use gfi::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let bench = Bench::new().with_budget(2.0).with_max_iters(20).with_env_overrides();
    let mut results: Vec<BenchResult> = Vec::new();
    let artifacts = std::path::Path::new("artifacts");
    let engine = Arc::new(Engine::new(
        artifacts.join("manifest.json").exists().then_some(artifacts),
    ));
    println!("pjrt available: {}", engine.has_pjrt());
    let mut mesh = gfi::mesh::icosphere(3);
    mesh.normalize_unit_box();
    let id = engine.register_mesh(mesh, "sphere");
    let n = engine.cloud(id).unwrap().scene.len();
    let mut rng = Rng::new(1);
    let field = Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.gaussian()).collect());

    let sf = IntegratorSpec::Sf(SfConfig::default());
    let rfd = IntegratorSpec::Rfd(RfdConfig { num_features: 16, ..Default::default() });
    let rfd_pjrt = IntegratorSpec::RfdPjrt(RfdConfig { num_features: 16, ..Default::default() });

    // Warm the caches, then measure the request path.
    let _ = engine.integrate(id, &sf, &field).unwrap();
    let _ = engine.integrate(id, &rfd, &field).unwrap();
    results.push(bench.run(&format!("engine/sf-cached/n={n}"), || {
        engine.integrate(id, &sf, &field).unwrap()
    }));
    results.push(bench.run(&format!("engine/rfd-cached/n={n}"), || {
        engine.integrate(id, &rfd, &field).unwrap()
    }));
    // Allocation-free serving path: caller-held output, pooled workspace.
    let mut out = Mat::zeros(n, 3);
    results.push(bench.run(&format!("engine/sf-cached-into/n={n}"), || {
        engine.integrate_into(id, &sf, &field, &mut out).unwrap()
    }));
    results.push(bench.run(&format!("engine/rfd-cached-into/n={n}"), || {
        engine.integrate_into(id, &rfd, &field, &mut out).unwrap()
    }));
    if engine.has_pjrt() {
        let _ = engine.integrate(id, &rfd_pjrt, &field).unwrap();
        results.push(bench.run(&format!("engine/rfd-pjrt/n={n}"), || {
            engine.integrate(id, &rfd_pjrt, &field).unwrap()
        }));
    }

    // Batcher throughput: 8 concurrent single-column requests.
    let batcher = Batcher::new(engine.clone(), BatcherConfig::default());
    let col = Mat::from_vec(n, 1, (0..n).map(|_| rng.gaussian()).collect());
    results.push(bench.run("batcher/8x1col-rfd", || {
        std::thread::scope(|s| {
            let hs: Vec<_> = (0..8)
                .map(|_| {
                    let b = &batcher;
                    let be = rfd.clone();
                    let c = col.clone();
                    s.spawn(move || b.integrate(id, be, c).unwrap())
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).count()
        })
    }));

    // Cache churn: more distinct clouds than the byte budget holds, so
    // every request pays eviction + transparent re-prepare. The delta vs
    // engine/rfd-cached is the full cost of a cache lifecycle turn.
    {
        let probe = Engine::new(None);
        let pid = probe.register_mesh(gfi::mesh::icosphere(2), "probe");
        let pn = probe.cloud(pid).unwrap().scene.len();
        let pfield = Mat::from_vec(pn, 3, (0..pn * 3).map(|_| rng.gaussian()).collect());
        probe.integrate(pid, &rfd, &pfield).unwrap();
        // Budget for ~2 of the 4 clouds' prepared integrators.
        let churn_engine = EngineConfig::default()
            .max_resident_bytes(probe.resident_bytes() * 5 / 2)
            .build();
        let ids: Vec<u64> = (0..4)
            .map(|i| {
                churn_engine.register_mesh(gfi::mesh::icosphere(2), &format!("churn-{i}"))
            })
            .collect();
        let mut turn = 0usize;
        results.push(bench.run(&format!("engine/cache_churn/n={pn}"), || {
            let cid = ids[turn % ids.len()];
            turn += 1;
            churn_engine.integrate(cid, &rfd, &pfield).unwrap()
        }));
        let stats = churn_engine.cache_stats();
        println!(
            "cache_churn: {} evictions, resident {} bytes",
            stats.integrators.evictions,
            churn_engine.resident_bytes()
        );
    }

    // Two-stage prepare pipeline (ISSUE 5): a kernel sweep over one
    // cloud shares one separator tree per (cloud, epoch), so after the
    // first prepare every re-prepare pays only the kernel stage (lookup
    // table evaluation) — engine/prepare_shared evicts the *integrator*
    // between turns but keeps the shared structure. engine/prepare_full
    // drops the structure too, paying the Dijkstra/tree stage every
    // turn; the gap between the two medians is the structure-stage work
    // a kernel sweep skips.
    {
        let sweep_engine = Engine::new(None);
        let mut smesh = gfi::mesh::icosphere(3);
        smesh.normalize_unit_box();
        let sid = sweep_engine.register_scene(Scene::from_mesh(&smesh), "sweep");
        let sn = sweep_engine.cloud(sid).unwrap().scene.len();
        let sfield = Mat::from_vec(sn, 3, (0..sn * 3).map(|_| rng.gaussian()).collect());
        let spec_of = |lam: f64| {
            IntegratorSpec::Sf(SfConfig {
                kernel: gfi::integrators::KernelFn::ExpNeg(lam),
                ..Default::default()
            })
        };
        // Acceptance: two specs differing only in kernel perform the
        // structure stage once (share counter = 1), and the shared
        // prepare is bitwise what a from-scratch prepare gives.
        let (out_a, info_a) = sweep_engine.integrate(sid, &spec_of(1.0), &sfield).unwrap();
        assert!(!info_a.structure_shared, "first prepare builds the structure");
        let (out_b, info_b) = sweep_engine.integrate(sid, &spec_of(2.0), &sfield).unwrap();
        assert!(info_b.structure_shared, "second kernel must reuse the structure");
        assert_eq!(
            sweep_engine.cache_stats().structures.hits,
            1,
            "kernel sweep of 2 specs must share the structure exactly once"
        );
        let sweep_scene = sweep_engine.cloud(sid).unwrap().scene.clone();
        for (lam, out) in [(1.0, &out_a), (2.0, &out_b)] {
            let fresh = gfi::integrators::prepare(&sweep_scene, &spec_of(lam)).unwrap();
            assert_eq!(
                out.data,
                fresh.apply(&sfield).data,
                "shared-structure prepare diverged from from-scratch (lam={lam})"
            );
        }
        println!(
            "prepare_shared acceptance: n={sn} share counter = 1, bitwise-identical"
        );
        let kernels = [1.0, 2.0, 4.0, 8.0];
        let mut turn = 0usize;
        results.push(bench.run(&format!("engine/prepare_shared/n={sn}"), || {
            let spec = spec_of(kernels[turn % kernels.len()]);
            turn += 1;
            // Drops the prepared integrator but keeps the shared tree:
            // this prepare is kernel-stage only.
            sweep_engine.evict_spec(sid, &spec).unwrap();
            sweep_engine.integrate(sid, &spec, &sfield).unwrap()
        }));
        let mut turn2 = 0usize;
        results.push(bench.run(&format!("engine/prepare_full/n={sn}"), || {
            let spec = spec_of(kernels[turn2 % kernels.len()]);
            turn2 += 1;
            // Drops integrators *and* structures: this prepare re-runs
            // the Dijkstra/tree structure stage.
            sweep_engine.evict_cloud_artifacts(sid);
            sweep_engine.integrate(sid, &spec, &sfield).unwrap()
        }));
    }

    // Mesh-dynamics frame updates on a 10k-node icosphere: every
    // iteration moves ~1% of the vertices (two alternating localized
    // bumps, so each update really changes geometry).
    // `engine/update_frame` pays update_cloud's incremental SF refresh +
    // one (cache-hit) request; `engine/update_frame_reprepare` drops the
    // artifacts instead and pays the full prepare on the request — the
    // gap between the two medians is the dynamic-scene win ROADMAP
    // tracks.
    {
        let mut dmesh = gfi::mesh::icosphere(5); // 10242 vertices
        dmesh.normalize_unit_box();
        let dn = dmesh.num_verts();
        let dyn_engine = Engine::new(None);
        let did = dyn_engine.register_scene(Scene::from_mesh(&dmesh), "dyn");
        let sf_spec = IntegratorSpec::Sf(SfConfig { separator_size: 8, ..Default::default() });
        let dfield = Mat::from_vec(dn, 3, (0..dn * 3).map(|_| rng.gaussian()).collect());
        dyn_engine.integrate(did, &sf_spec, &dfield).unwrap(); // warm
        let frame = |center: usize| -> PointCloud {
            PointCloud::new(gfi::mesh::radial_bump(&dmesh.verts, center, dn / 100, 0.03))
        };
        let frames = [frame(11), frame(9173)];
        // Acceptance check (ISSUE 4): a 1%-vertex perturbation refreshes
        // to something bitwise-identical to a full prepare while reusing
        // the majority of the separator tree.
        let info = dyn_engine
            .update_cloud(did, frames[0].clone(), &UpdateOpts::default())
            .unwrap();
        assert!(
            info.reused_nodes > info.rebuilt_nodes,
            "refresh must reuse the majority of the tree: {info:?}"
        );
        let (out, served) = dyn_engine.integrate(did, &sf_spec, &dfield).unwrap();
        assert!(served.cache_hit, "refreshed artifact must serve the request");
        let fresh = gfi::integrators::prepare(&dyn_engine.cloud(did).unwrap().scene, &sf_spec)
            .unwrap();
        assert_eq!(
            out.data,
            fresh.apply(&dfield).data,
            "refresh diverged from a full prepare"
        );
        println!(
            "update_frame acceptance: n={dn} dirty={} reused={}/{} bitwise-identical",
            info.dirty,
            info.reused_nodes,
            info.reused_nodes + info.rebuilt_nodes
        );
        let mut turn = 0usize;
        results.push(bench.run(&format!("engine/update_frame/n={dn}"), || {
            turn += 1;
            dyn_engine
                .update_cloud(did, frames[turn % 2].clone(), &UpdateOpts::default())
                .unwrap();
            dyn_engine.integrate(did, &sf_spec, &dfield).unwrap()
        }));
        let mut turn2 = 1usize;
        results.push(bench.run(&format!("engine/update_frame_reprepare/n={dn}"), || {
            turn2 += 1;
            dyn_engine
                .update_cloud(
                    did,
                    frames[turn2 % 2].clone(),
                    &UpdateOpts { refresh: false, ..Default::default() },
                )
                .unwrap();
            dyn_engine.integrate(did, &sf_spec, &dfield).unwrap()
        }));
    }

    // Persistent store, warm restart (ISSUE 7): every iteration builds a
    // *fresh* engine (empty RAM tier — a process restart) and pays one
    // SF prepare at n=10242. cold_dir pays the full structure stage;
    // warm_dir finds the previous engine's spill on disk and pays only
    // validated decode + kernel stage. The gap is the restart win the
    // store exists for — asserted ≥5× on the medians, and the disk-served
    // output is asserted bitwise-identical to the cold computation.
    {
        let mut wmesh = gfi::mesh::icosphere(5); // 10242 vertices
        wmesh.normalize_unit_box();
        let wn = wmesh.num_verts();
        let wscene = Scene::from_mesh(&wmesh);
        let spec = IntegratorSpec::Sf(SfConfig { separator_size: 8, ..Default::default() });
        let wfield = Mat::from_vec(wn, 1, (0..wn).map(|_| rng.gaussian()).collect());
        let cold_dir =
            std::env::temp_dir().join(format!("gfi_bench_cold_{}", std::process::id()));
        let warm_dir =
            std::env::temp_dir().join(format!("gfi_bench_warm_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&cold_dir);
        let _ = std::fs::remove_dir_all(&warm_dir);
        // Populate the warm dir once and record the oracle output.
        let oracle_out = {
            let warmer =
                EngineConfig::default().artifacts(&warm_dir).store(true).build();
            let wid = warmer.register_scene(wscene.clone(), "warm");
            warmer.integrate(wid, &spec, &wfield).unwrap().0
        }; // dropped: RAM tier gone, spill file survives
        let cold = bench.run(&format!("engine/cold_start_cold_dir/n={wn}"), || {
            let e = EngineConfig::default().artifacts(&cold_dir).store(true).build();
            let id = e.register_scene(wscene.clone(), "cold");
            let (out, info) = e.integrate(id, &spec, &wfield).unwrap();
            assert!(!info.structure_shared, "cold dir must rebuild the structure");
            assert_eq!(out.data, oracle_out.data, "cold start diverged");
            // Purge the spill so the next iteration starts cold again.
            e.unregister_cloud(id);
        });
        let warm = bench.run(&format!("engine/cold_start_warm_dir/n={wn}"), || {
            let e = EngineConfig::default().artifacts(&warm_dir).store(true).build();
            let id = e.register_scene(wscene.clone(), "warm");
            let (out, info) = e.integrate(id, &spec, &wfield).unwrap();
            assert!(info.structure_shared, "warm dir must serve the structure from disk");
            assert_eq!(out.data, oracle_out.data, "warm restart diverged");
        });
        println!(
            "cold_start acceptance: n={wn} cold {:.1}ms vs warm {:.1}ms ({:.1}x), \
             bitwise-identical",
            cold.median * 1e3,
            warm.median * 1e3,
            cold.median / warm.median
        );
        assert!(
            warm.median * 5.0 <= cold.median,
            "warm restart must be >=5x faster than a cold start: cold {:.1}ms vs warm {:.1}ms",
            cold.median * 1e3,
            warm.median * 1e3
        );
        results.push(cold);
        results.push(warm);
        let _ = std::fs::remove_dir_all(&cold_dir);
        let _ = std::fs::remove_dir_all(&warm_dir);
    }

    serve_benches(&bench, &mut results);

    write_json("BENCH_coordinator.json", &results).expect("write BENCH_coordinator.json");
}

/// Serving-tier benches (ISSUE 10): 64 concurrent clients, each issuing
/// 32 same-shaped `integrate` requests against a tiny (n=12) cloud, so
/// the measurement is transport-bound rather than compute-bound.
///
/// * `serve/throughput-threaded` — classic request-response over the
///   blocking thread-per-connection JSON server: every request pays a
///   write syscall, a cross-thread wakeup ping-pong, and a read syscall
///   before the client may send the next one.
/// * `serve/throughput-evented` — the same 2048 requests as pipelined
///   binary frames: each client writes its whole burst in one `write`
///   and drains responses in bulk. Measured with the micro-batching
///   window off (`batch_window_us: 0`) so the case isolates the
///   transport; coalescing correctness and counters are proven by
///   `tests/serving.rs`. The in-bench assert holds the evented burst to
///   >=4x the threaded throughput at equal `max_connections`.
/// * `serve/throughput-evented-batched` — same burst through a 200us
///   batching window (reported, not gated: the window trades a little
///   burst throughput for cross-connection coalescing).
/// * `serve/p99-evented` — per-request latency (burst write start ->
///   response frame arrival) across three instrumented bursts; the
///   `median` slot of this hand-built result carries the p99 so it lands
///   in `BENCH_coordinator.json` alongside the medians.
#[cfg(unix)]
fn serve_benches(bench: &Bench, results: &mut Vec<BenchResult>) {
    use gfi::coordinator::evented::serve_evented_with;
    use gfi::coordinator::frame::{self, opcode};
    use gfi::coordinator::server::{serve_with, ServerConfig};
    use gfi::util::json::{parse, Json};
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::sync::mpsc;
    use std::time::Instant;

    const CLIENTS: usize = 64;
    const REQS: usize = 32;
    const TOTAL: usize = CLIENTS * REQS;

    let make_engine = || {
        let e = Arc::new(Engine::new(None));
        let mut m = gfi::mesh::icosphere(0); // 12 vertices
        m.normalize_unit_box();
        let id = e.register_mesh(m, "serve");
        (e, id)
    };
    let (engine_t, cid_t) = make_engine();
    let (engine_e, cid_e) = make_engine();
    assert_eq!(cid_t, cid_e, "fresh engines assign the same first cloud id");
    let cid = cid_t;

    let spawn_threaded = |engine: Arc<Engine>, cfg: ServerConfig| {
        let (tx, rx) = mpsc::channel();
        let h = std::thread::spawn(move || {
            serve_with(engine, "127.0.0.1:0", cfg, move |a| tx.send(a).unwrap()).unwrap();
        });
        (rx.recv().unwrap(), h)
    };
    let spawn_evented = |engine: Arc<Engine>, cfg: ServerConfig| {
        let (tx, rx) = mpsc::channel();
        let h = std::thread::spawn(move || {
            serve_evented_with(engine, "127.0.0.1:0", cfg, move |a| tx.send(a).unwrap())
                .unwrap();
        });
        (rx.recv().unwrap(), h)
    };
    let (addr_t, join_t) = spawn_threaded(
        engine_t.clone(),
        ServerConfig { max_connections: CLIENTS, ..Default::default() },
    );
    let (addr_e, join_e) = spawn_evented(
        engine_e.clone(),
        ServerConfig {
            max_connections: CLIENTS,
            batch_window_us: 0,
            ..Default::default()
        },
    );
    let (addr_b, join_b) = spawn_evented(
        engine_e.clone(),
        ServerConfig {
            max_connections: CLIENTS,
            batch_window_us: 200,
            ..Default::default()
        },
    );

    // One integrate payload per client: same (cloud, spec), distinct
    // field values — exactly the shape the batcher coalesces.
    let payloads: Vec<String> = (0..CLIENTS)
        .map(|i| {
            let mut rng = Rng::new(500 + i as u64);
            let field: Vec<String> =
                (0..12).map(|_| format!("{}", rng.gaussian())).collect();
            format!(
                r#"{{"cloud":{cid},"backend":"rfd","field":[{}],"d":1,"m":8,"seed":3}}"#,
                field.join(",")
            )
        })
        .collect();
    // Line-JSON form for the threaded server ...
    let lines: Vec<Vec<u8>> = payloads
        .iter()
        .map(|p| format!("{{\"op\":\"integrate\",{}\n", &p[1..]).into_bytes())
        .collect();
    // ... and the whole pipelined burst as one precomputed byte blob for
    // the evented server.
    let blobs: Vec<Vec<u8>> = payloads
        .iter()
        .map(|p| {
            let mut b = Vec::new();
            for j in 0..REQS {
                b.extend_from_slice(&frame::encode(
                    opcode::INTEGRATE,
                    j as u64 + 1,
                    p.as_bytes(),
                ));
            }
            b
        })
        .collect();

    let has = |hay: &[u8], needle: &[u8]| hay.windows(needle.len()).any(|w| w == needle);
    let json_roundtrip = |c: &mut TcpStream, line: &[u8]| -> Json {
        c.write_all(line).unwrap();
        let mut buf = Vec::new();
        let mut chunk = [0u8; 4096];
        loop {
            let n = c.read(&mut chunk).unwrap();
            assert!(n > 0, "server closed before replying");
            buf.extend_from_slice(&chunk[..n]);
            if buf.last() == Some(&b'\n') {
                break;
            }
        }
        parse(std::str::from_utf8(&buf).unwrap().trim()).unwrap()
    };
    let bin_roundtrip = |c: &mut TcpStream, op: u8, id: u64, payload: &str| -> Json {
        c.write_all(&frame::encode(op, id, payload.as_bytes())).unwrap();
        let mut buf = Vec::new();
        let mut chunk = [0u8; 16 * 1024];
        loop {
            if let Some((f, _)) = frame::decode(&buf).expect("well-formed response") {
                assert_eq!((f.op, f.id), (op, id));
                return parse(&String::from_utf8(f.payload).unwrap()).unwrap();
            }
            let n = c.read(&mut chunk).unwrap();
            assert!(n > 0, "server closed before replying");
            buf.extend_from_slice(&chunk[..n]);
        }
    };

    // Bitwise probe across transports, on short-lived connections BEFORE
    // the persistent fleet saturates max_connections: the same request
    // through blocking-JSON and through evented-binary must parse to
    // bit-identical result arrays (distinct engines, so nothing is
    // shared but the computation).
    {
        let mut rng = Rng::new(999);
        let field: Vec<String> = (0..12).map(|_| format!("{}", rng.gaussian())).collect();
        let probe = format!(
            r#"{{"cloud":{cid},"backend":"rfd","field":[{}],"d":1,"m":8,"seed":3}}"#,
            field.join(",")
        );
        let mut ct = TcpStream::connect(addr_t).unwrap();
        let rt = json_roundtrip(
            &mut ct,
            format!("{{\"op\":\"integrate\",{}\n", &probe[1..]).as_bytes(),
        );
        let mut ce = TcpStream::connect(addr_e).unwrap();
        let re = bin_roundtrip(&mut ce, opcode::INTEGRATE, 7, &probe);
        let a = rt.get("result").and_then(Json::as_f64_vec).unwrap();
        let b = re.get("result").and_then(Json::as_f64_vec).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "evented binary result diverged from the blocking JSON server"
            );
        }
        println!("serve acceptance: cross-transport bitwise-identical probe passed");
    }
    // Let the probe handlers retire before filling the connection cap.
    std::thread::sleep(std::time::Duration::from_millis(50));

    let connect_fleet = |addr: std::net::SocketAddr| -> Vec<TcpStream> {
        (0..CLIENTS)
            .map(|_| {
                let c = TcpStream::connect(addr).unwrap();
                c.set_nodelay(true).unwrap();
                c
            })
            .collect()
    };

    // One burst over the blocking server: every client runs its 32
    // requests strictly request-response.
    let threaded_burst = |conns: &mut [TcpStream], lines: &[Vec<u8>]| {
        std::thread::scope(|s| {
            for (i, c) in conns.iter_mut().enumerate() {
                let line = &lines[i];
                let has = &has;
                s.spawn(move || {
                    let mut buf = Vec::with_capacity(4096);
                    let mut chunk = [0u8; 4096];
                    for _ in 0..REQS {
                        c.write_all(line).unwrap();
                        buf.clear();
                        loop {
                            let n = c.read(&mut chunk).unwrap();
                            assert!(n > 0, "threaded server closed mid-burst");
                            buf.extend_from_slice(&chunk[..n]);
                            if buf.last() == Some(&b'\n') {
                                break;
                            }
                        }
                        assert!(has(&buf, b"\"ok\":true"), "request failed mid-burst");
                    }
                });
            }
        });
    };
    // One burst over the evented server: every client writes its whole
    // pipelined blob at once, then drains 32 in-order response frames.
    // Returns per-response latencies when `record` is set (the p99 pass).
    let evented_burst = |conns: &mut [TcpStream], blobs: &[Vec<u8>], record: bool| {
        std::thread::scope(|s| {
            let handles: Vec<_> = conns
                .iter_mut()
                .enumerate()
                .map(|(i, c)| {
                    let blob = &blobs[i];
                    let has = &has;
                    s.spawn(move || {
                        let start = Instant::now();
                        c.write_all(blob).unwrap();
                        let mut lat = Vec::new();
                        let mut buf = Vec::with_capacity(16 * 1024);
                        let mut chunk = [0u8; 16 * 1024];
                        let mut got = 0usize;
                        while got < REQS {
                            let n = c.read(&mut chunk).unwrap();
                            assert!(n > 0, "evented server closed mid-burst");
                            buf.extend_from_slice(&chunk[..n]);
                            while let Some((f, used)) =
                                frame::decode(&buf).expect("well-formed response")
                            {
                                buf.drain(..used);
                                got += 1;
                                assert_eq!(f.id as usize, got, "responses out of order");
                                assert!(
                                    has(&f.payload, b"\"ok\":true"),
                                    "request failed mid-burst"
                                );
                                if record {
                                    lat.push(start.elapsed().as_secs_f64());
                                }
                            }
                        }
                        lat
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect::<Vec<f64>>()
        })
    };

    // Threaded baseline.
    let mut conns_t = connect_fleet(addr_t);
    threaded_burst(&mut conns_t, &lines); // warm prepare + caches
    let threaded = bench.run(&format!("serve/throughput-threaded/reqs={TOTAL}"), || {
        threaded_burst(&mut conns_t, &lines)
    });
    drop(conns_t);
    std::thread::sleep(std::time::Duration::from_millis(50));
    let mut ct = TcpStream::connect(addr_t).unwrap();
    json_roundtrip(&mut ct, b"{\"op\":\"shutdown\"}\n");
    drop(ct);
    join_t.join().unwrap();

    // Evented, batching window off: pure event-loop pipelining.
    let mut conns_e = connect_fleet(addr_e);
    evented_burst(&mut conns_e, &blobs, false); // warm
    let evented = bench.run(&format!("serve/throughput-evented/reqs={TOTAL}"), || {
        evented_burst(&mut conns_e, &blobs, false)
    });
    // Tail latency under the same load, instrumented per response.
    let mut lat: Vec<f64> = Vec::with_capacity(3 * TOTAL);
    for _ in 0..3 {
        lat.extend(evented_burst(&mut conns_e, &blobs, true));
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99_idx = ((lat.len() * 99) / 100).min(lat.len() - 1);
    let p99 = BenchResult {
        name: format!("serve/p99-evented/reqs={TOTAL}"),
        iters: lat.len(),
        min: lat[0],
        median: lat[p99_idx], // the p99 — this result reports tail, not center
        max: *lat.last().unwrap(),
        mean: lat.iter().sum::<f64>() / lat.len() as f64,
    };
    drop(conns_e);
    std::thread::sleep(std::time::Duration::from_millis(50));
    let mut ce = TcpStream::connect(addr_e).unwrap();
    bin_roundtrip(&mut ce, opcode::SHUTDOWN, 1, "{}");
    drop(ce);
    join_e.join().unwrap();

    // Evented with the 200us coalescing window, reported alongside.
    let mut conns_b = connect_fleet(addr_b);
    evented_burst(&mut conns_b, &blobs, false); // warm
    let batched = bench.run(&format!("serve/throughput-evented-batched/reqs={TOTAL}"), || {
        evented_burst(&mut conns_b, &blobs, false)
    });
    // The burst is same-(cloud, spec) across all 64 connections, so with
    // >=2 batcher submitters the window must have coalesced something.
    let stats = bin_roundtrip(&mut conns_b[0], opcode::STATS, 9001, "{}");
    let b = stats.get("batcher").unwrap();
    assert_eq!(b.get("enabled"), Some(&Json::Bool(true)));
    let coalesced = b.get("coalesced_requests").unwrap().as_usize().unwrap();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    if cores >= 2 {
        assert!(
            coalesced >= 2,
            "64 same-(cloud, spec) pipelined clients never coalesced \
             (coalesced_requests = {coalesced})"
        );
    }
    drop(conns_b);
    std::thread::sleep(std::time::Duration::from_millis(50));
    let mut cb = TcpStream::connect(addr_b).unwrap();
    bin_roundtrip(&mut cb, opcode::SHUTDOWN, 2, "{}");
    drop(cb);
    join_b.join().unwrap();

    let throughput = |r: &BenchResult| TOTAL as f64 / r.median;
    println!(
        "serve acceptance: threaded {:.0} req/s vs evented {:.0} req/s ({:.1}x), \
         batched-window {:.0} req/s, p99 {:.2}ms, coalesced_requests {}",
        throughput(&threaded),
        throughput(&evented),
        threaded.median / evented.median,
        throughput(&batched),
        p99.median * 1e3,
        coalesced
    );
    assert!(
        threaded.median >= 4.0 * evented.median,
        "pipelined evented serving must sustain >=4x the thread-per-connection \
         JSON throughput: threaded {:.2}ms vs evented {:.2}ms per {TOTAL}-request burst",
        threaded.median * 1e3,
        evented.median * 1e3
    );
    results.push(threaded);
    results.push(evented);
    results.push(batched);
    results.push(p99);
}

#[cfg(not(unix))]
fn serve_benches(_bench: &Bench, _results: &mut Vec<BenchResult>) {}
