//! Integrator hot-path benchmarks (criterion-lite; `cargo bench`).
//! Covers the workloads behind Fig. 4: SF/RFD/tree/BF pre-processing
//! (through the `prepare` factory) and apply at two mesh scales, the
//! allocation-free `apply_into` serving path, the n=2048 acceptance
//! workloads for the blocked-GEMM + batched-distance kernel layers, plus
//! the Hankel/FFT and matmul substrate. Writes `BENCH_integrators.json`
//! (median ns per case) so the perf trajectory is tracked across PRs —
//! CI diffs it against the previous run's artifact.

use gfi::fft::hankel_matvec_multi;
use gfi::integrators::rfd::RfdConfig;
use gfi::integrators::sf::SfConfig;
use gfi::integrators::trees::TreeKind;
use gfi::integrators::{prepare, FieldIntegrator, IntegratorSpec, KernelFn, Scene, Workspace};
use gfi::linalg::Mat;
use gfi::util::bench::{write_json, Bench, BenchResult};
use gfi::util::rng::Rng;

fn main() {
    let bench = Bench::new().with_budget(2.0).with_max_iters(12).with_env_overrides();
    let mut results: Vec<BenchResult> = Vec::new();
    for subdiv in [3usize, 4] {
        let mut mesh = gfi::mesh::icosphere(subdiv);
        mesh.normalize_unit_box();
        let scene = Scene::from_mesh(&mesh);
        let n = scene.len();
        let mut rng = Rng::new(1);
        let field = Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.gaussian()).collect());
        let mut out = Mat::zeros(n, 3);
        let mut ws = Workspace::new();

        let sf_spec = IntegratorSpec::Sf(SfConfig {
            kernel: KernelFn::ExpNeg(4.0),
            ..Default::default()
        });
        results.push(bench.run(&format!("sf/preprocess/n={n}"), || {
            prepare(&scene, &sf_spec).unwrap()
        }));
        let sf: Box<dyn FieldIntegrator> = prepare(&scene, &sf_spec).unwrap();
        results.push(bench.run(&format!("sf/apply/n={n}"), || sf.apply(&field)));
        results.push(bench.run(&format!("sf/apply_into/n={n}"), || {
            sf.apply_into(&field, &mut out, &mut ws)
        }));
        // General-f (FFT) path.
        let sf_gen = prepare(
            &scene,
            &IntegratorSpec::Sf(SfConfig {
                kernel: KernelFn::GaussianSq(4.0),
                ..Default::default()
            }),
        )
        .unwrap();
        results.push(bench.run(&format!("sf/apply-generalf/n={n}"), || sf_gen.apply(&field)));

        let rfd_spec = IntegratorSpec::Rfd(RfdConfig {
            num_features: 32,
            epsilon: 0.15,
            lambda: -0.5,
            ..Default::default()
        });
        results.push(bench.run(&format!("rfd/preprocess/n={n}"), || {
            prepare(&scene, &rfd_spec).unwrap()
        }));
        let rfd = prepare(&scene, &rfd_spec).unwrap();
        results.push(bench.run(&format!("rfd/apply/n={n}"), || rfd.apply(&field)));
        results.push(bench.run(&format!("rfd/apply_into/n={n}"), || {
            rfd.apply_into(&field, &mut out, &mut ws)
        }));

        let trees = prepare(
            &scene,
            &IntegratorSpec::Trees { kind: TreeKind::Bartal, count: 3, lambda: 4.0, seed: 0 },
        )
        .unwrap();
        results.push(bench.run(&format!("trees-bartal3/apply/n={n}"), || trees.apply(&field)));

        if n <= 1000 {
            let bf_spec = IntegratorSpec::BfSp(KernelFn::ExpNeg(4.0));
            results.push(bench.run(&format!("bf/preprocess/n={n}"), || {
                prepare(&scene, &bf_spec).unwrap()
            }));
            let bf = prepare(&scene, &bf_spec).unwrap();
            results.push(bench.run(&format!("bf/apply/n={n}"), || bf.apply(&field)));
        }
    }

    // Acceptance workloads (ISSUE 1): pre-processing throughput at
    // n=2048 — RFD (blocked GEMM Gram + Woodbury core) on a random cloud,
    // BF shortest-path kernel (batched parallel Dijkstra) on its ε-graph.
    {
        let mut rng = Rng::new(7);
        let pc = gfi::pointcloud::random_cloud(2048, &mut rng);
        let g = pc.epsilon_graph(0.15, gfi::pointcloud::Norm::LInf, true);
        let scene = Scene::new(pc, Some(g));
        let spec = IntegratorSpec::Rfd(RfdConfig {
            num_features: 32,
            epsilon: 0.15,
            lambda: -0.5,
            ..Default::default()
        });
        results.push(bench.run("rfd/preprocess/n=2048", || {
            prepare(&scene, &spec).unwrap()
        }));
        let rfd = prepare(&scene, &spec).unwrap();
        let field = Mat::from_vec(2048, 3, (0..2048 * 3).map(|_| rng.gaussian()).collect());
        results.push(bench.run("rfd/apply/n=2048", || rfd.apply(&field)));
        results.push(bench.run("bf/preprocess/n=2048", || {
            prepare(&scene, &IntegratorSpec::BfSp(KernelFn::ExpNeg(4.0))).unwrap()
        }));
    }

    // Substrate: Hankel multiply + dense matmul.
    let mut rng = Rng::new(2);
    for d in [256usize, 2048] {
        let h: Vec<f64> = (0..2 * d).map(|_| rng.gaussian()).collect();
        let z: Vec<f64> = (0..d * 3).map(|_| rng.gaussian()).collect();
        results.push(bench.run(&format!("hankel/fft-multi3/D={d}"), || {
            hankel_matvec_multi(&h, &z, d, 3)
        }));
    }
    let a = Mat::from_vec(512, 512, (0..512 * 512).map(|_| rng.gaussian()).collect());
    results.push(bench.run("linalg/matmul/512", || a.matmul(&a)));
    let b512 = Mat::from_vec(512, 512, (0..512 * 512).map(|_| rng.gaussian()).collect());
    results.push(bench.run("linalg/t_matmul/512", || a.t_matmul(&b512)));

    // SIMD dispatch differential (PR 8): identical workloads under pinned
    // scalar vs native dispatch, covering the three explicit microkernel
    // sites — the GEMM tile, the kernel-table fill (Rational is the fully
    // vectorized profile), and BF preprocessing (batched Dijkstra + table
    // fill). The crate builds with `-C target-cpu=native`, so LLVM
    // already auto-vectorizes the scalar oracles where it can; the gate
    // is therefore a *no-regression parity* assert (native ≤ 1.15×
    // scalar) on CPUs with vector kernels, and the printed ratio is the
    // tracked number (ROADMAP carries the ≥2× aspiration for the
    // gather-bound fills on toolchains without autovectorization).
    {
        use gfi::util::simd::{self, SimdMode};
        let detected = simd::kernel_name(); // honors GFI_SIMD
        let mut rng = Rng::new(3);
        let a384 = Mat::from_vec(384, 384, (0..384 * 384).map(|_| rng.gaussian()).collect());
        let mut dist = Mat::zeros(512, 512);
        for v in dist.data.iter_mut() {
            *v = rng.gaussian().abs() * 4.0;
        }
        let kf = KernelFn::Rational(1.0);
        let mut rng2 = Rng::new(7);
        let pc = gfi::pointcloud::random_cloud(1024, &mut rng2);
        let g = pc.epsilon_graph(0.2, gfi::pointcloud::Norm::LInf, true);
        let scene1k = Scene::new(pc, Some(g));
        let bf_spec = IntegratorSpec::BfSp(KernelFn::ExpNeg(4.0));

        let mut pairs = Vec::new();
        for (mode, tag) in [(SimdMode::Scalar, "scalar"), (SimdMode::Native, "native")] {
            simd::set_override(Some(mode));
            let mm = bench.run(&format!("simd/matmul-{tag}/384"), || a384.matmul(&a384));
            let kt = bench.run(&format!("simd/kernel-table-{tag}/512"), || {
                gfi::integrators::artifacts::sp_kernel_map(&dist, &kf)
            });
            let bf = bench.run(&format!("simd/bf-preprocess-{tag}/1024"), || {
                prepare(&scene1k, &bf_spec).unwrap()
            });
            pairs.push([mm, kt, bf]);
        }
        simd::set_override(None);
        let [scalar_runs, native_runs] = [pairs.remove(0), pairs.remove(0)];
        for (s, v) in scalar_runs.iter().zip(&native_runs) {
            let ratio = s.median / v.median;
            println!("simd speedup {}: {ratio:.2}x (kernel: {detected})", v.name);
            if detected != "scalar" {
                // Parity gate: explicit SIMD must never lose to the
                // (auto-vectorized) scalar oracle by more than noise.
                assert!(
                    v.median <= s.median * 1.15,
                    "{}: native ({:.0} ns) regressed vs scalar ({:.0} ns)",
                    v.name,
                    v.median * 1e9,
                    s.median * 1e9
                );
            }
        }
        results.extend(scalar_runs);
        results.extend(native_runs);
    }

    let out = "BENCH_integrators.json";
    match write_json(out, &results) {
        Ok(()) => println!("\nwrote {out} ({} benchmarks)", results.len()),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}
