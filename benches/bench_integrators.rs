//! Integrator hot-path benchmarks (criterion-lite; `cargo bench`).
//! Covers the workloads behind Fig. 4: SF/RFD/tree/BF pre-processing and
//! apply at two mesh scales, the n=2048 acceptance workloads for the
//! blocked-GEMM + batched-distance kernel layers, plus the Hankel/FFT and
//! matmul substrate. Writes `BENCH_integrators.json` (median ns per case)
//! so the perf trajectory is tracked from PR 1 onward.

use gfi::fft::hankel_matvec_multi;
use gfi::integrators::bf::BruteForceSp;
use gfi::integrators::rfd::{RfDiffusion, RfdConfig};
use gfi::integrators::sf::{SeparatorFactorization, SfConfig};
use gfi::integrators::trees::{TreeEnsembleIntegrator, TreeKind};
use gfi::integrators::{FieldIntegrator, KernelFn};
use gfi::linalg::Mat;
use gfi::util::bench::{write_json, Bench, BenchResult};
use gfi::util::rng::Rng;

fn main() {
    let bench = Bench::new().with_budget(2.0).with_max_iters(12).with_env_overrides();
    let mut results: Vec<BenchResult> = Vec::new();
    for subdiv in [3usize, 4] {
        let mut mesh = gfi::mesh::icosphere(subdiv);
        mesh.normalize_unit_box();
        let g = mesh.to_graph();
        let n = g.n;
        let pc = gfi::pointcloud::PointCloud::new(mesh.verts.clone());
        let mut rng = Rng::new(1);
        let field = Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.gaussian()).collect());

        let sf_cfg = SfConfig { kernel: KernelFn::ExpNeg(4.0), ..Default::default() };
        results.push(bench.run(&format!("sf/preprocess/n={n}"), || {
            SeparatorFactorization::new(&g, sf_cfg.clone())
        }));
        let sf = SeparatorFactorization::new(&g, sf_cfg.clone());
        results.push(bench.run(&format!("sf/apply/n={n}"), || sf.apply(&field)));
        // General-f (FFT) path.
        let sf_gen = SeparatorFactorization::new(
            &g,
            SfConfig { kernel: KernelFn::GaussianSq(4.0), ..sf_cfg.clone() },
        );
        results.push(bench.run(&format!("sf/apply-generalf/n={n}"), || sf_gen.apply(&field)));

        let rfd_cfg = RfdConfig {
            num_features: 32,
            epsilon: 0.15,
            lambda: -0.5,
            ..Default::default()
        };
        results.push(bench.run(&format!("rfd/preprocess/n={n}"), || {
            RfDiffusion::new(&pc, rfd_cfg.clone())
        }));
        let rfd = RfDiffusion::new(&pc, rfd_cfg.clone());
        results.push(bench.run(&format!("rfd/apply/n={n}"), || rfd.apply(&field)));

        let trees = TreeEnsembleIntegrator::new(&g, TreeKind::Bartal, 3, 4.0, 0);
        results.push(bench.run(&format!("trees-bartal3/apply/n={n}"), || trees.apply(&field)));

        if n <= 1000 {
            results.push(bench.run(&format!("bf/preprocess/n={n}"), || {
                BruteForceSp::new(&g, &KernelFn::ExpNeg(4.0))
            }));
            let bf = BruteForceSp::new(&g, &KernelFn::ExpNeg(4.0));
            results.push(bench.run(&format!("bf/apply/n={n}"), || bf.apply(&field)));
        }
    }

    // Acceptance workloads (ISSUE 1): pre-processing throughput at
    // n=2048 — RFD (blocked GEMM Gram + Woodbury core) on a random cloud,
    // BF shortest-path kernel (batched parallel Dijkstra) on its ε-graph.
    {
        let mut rng = Rng::new(7);
        let pc = gfi::pointcloud::random_cloud(2048, &mut rng);
        let cfg = RfdConfig {
            num_features: 32,
            epsilon: 0.15,
            lambda: -0.5,
            ..Default::default()
        };
        results.push(bench.run("rfd/preprocess/n=2048", || {
            RfDiffusion::new(&pc, cfg.clone())
        }));
        let rfd = RfDiffusion::new(&pc, cfg.clone());
        let field = Mat::from_vec(2048, 3, (0..2048 * 3).map(|_| rng.gaussian()).collect());
        results.push(bench.run("rfd/apply/n=2048", || rfd.apply(&field)));
        let g = pc.epsilon_graph(0.15, gfi::pointcloud::Norm::LInf, true);
        results.push(bench.run("bf/preprocess/n=2048", || {
            BruteForceSp::new(&g, &KernelFn::ExpNeg(4.0))
        }));
    }

    // Substrate: Hankel multiply + dense matmul.
    let mut rng = Rng::new(2);
    for d in [256usize, 2048] {
        let h: Vec<f64> = (0..2 * d).map(|_| rng.gaussian()).collect();
        let z: Vec<f64> = (0..d * 3).map(|_| rng.gaussian()).collect();
        results.push(bench.run(&format!("hankel/fft-multi3/D={d}"), || {
            hankel_matvec_multi(&h, &z, d, 3)
        }));
    }
    let a = Mat::from_vec(512, 512, (0..512 * 512).map(|_| rng.gaussian()).collect());
    results.push(bench.run("linalg/matmul/512", || a.matmul(&a)));
    let b512 = Mat::from_vec(512, 512, (0..512 * 512).map(|_| rng.gaussian()).collect());
    results.push(bench.run("linalg/t_matmul/512", || a.t_matmul(&b512)));

    let out = "BENCH_integrators.json";
    match write_json(out, &results) {
        Ok(()) => println!("\nwrote {out} ({} benchmarks)", results.len()),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}
