"""L2 — the JAX RFDiffusion pipeline (paper Eq. 12), calling the L1
Pallas feature kernel, lowered once by aot.py to HLO text and executed
from the Rust coordinator via PJRT.

    rfd_apply(points, omegas, qscale, x, lam) =
        e^{-Λδ} (x + A [exp(Λ BᵀA) − I](BᵀA)⁻¹ Bᵀ x)

All shapes are static per artifact bucket (N, m, d); the Rust runtime
pads requests to the nearest bucket.
"""

import jax
import jax.numpy as jnp

from .kernels.rf_features import rf_features

# Taylor degree for the scaled exp/φ₁ series.
_TAYLOR_DEG = 18
# Fixed doubling-loop length (covers ‖ΛG‖₁ up to 2^40).
_MAX_DOUBLINGS = 40


def _expm_phi1(x):
    """(exp(X), φ₁(X)) with φ₁(X) = Σ_{j≥0} X^j/(j+1)! — matmuls only.

    The obvious `[exp(ΛG) − I](ΛG)⁻¹` needs a linear solve, which JAX
    lowers to a LAPACK typed-FFI custom call that the image's
    xla_extension 0.5.1 cannot compile. Instead we use the φ₁ identity
    (`[exp(X) − I]X⁻¹ = φ₁(X)`) computed by a Taylor series after
    scaling, then the doubling recurrences
    `exp(2X) = exp(X)²`, `φ₁(2X) = (exp(X) + I) φ₁(X) / 2`.
    The doubling count is data-dependent but the loop is fixed-length
    with masked updates, keeping the lowered HLO static.
    """
    m2 = x.shape[0]
    eye = jnp.eye(m2, dtype=x.dtype)
    norm = jnp.maximum(jnp.max(jnp.sum(jnp.abs(x), axis=0)), 1e-30)
    k = jnp.maximum(jnp.ceil(jnp.log2(norm)) + 1.0, 0.0)  # scaled norm ≤ ½
    alpha = 2.0 ** k
    xs = x / alpha
    e = eye
    p = eye
    term = eye
    for j in range(1, _TAYLOR_DEG + 1):
        term = term @ xs / j
        e = e + term
        p = p + term / (j + 1)

    def body(i, carry):
        e, p = carry
        do = (i < k).astype(x.dtype)
        e2 = e @ e
        p2 = (e + eye) @ p / 2.0
        return (do * e2 + (1.0 - do) * e, do * p2 + (1.0 - do) * p)

    e, p = jax.lax.fori_loop(0, _MAX_DOUBLINGS, body, (e, p))
    return e, p


def rfd_apply(points, omegas, qscale, x, lam, mask):
    """RFD graph-field integration.

    Args:
      points: (N, 3) f32 point cloud (unit-box normalized).
      omegas: (m, 3) f32 frequencies (σ-scaled truncated Gaussian).
      qscale: (m,) f32 importance weights q_j/m.
      x: (N, d) f32 field to integrate.
      lam: () f32 diffusion coefficient Λ.
      mask: (N,) f32 — 1 for real points, 0 for bucket padding. Masked
        rows are excluded *exactly*: their features are zeroed before the
        Gram/core computation, so padding never perturbs real outputs.

    Returns:
      (N, d) f32 ≈ exp(Λ(W_G − δI)) x on the masked subgraph.
    """
    a, b = rf_features(points, omegas, qscale)  # L1 Pallas kernel
    a = a * mask[:, None]
    b = b * mask[:, None]
    g = b.T @ a  # (2m, 2m)
    # [exp(ΛG) − I] G⁻¹ = Λ·φ₁(ΛG): no linear solve needed.
    _, phi1 = _expm_phi1(lam * g)
    bt_x = b.T @ x
    y = x + a @ (lam * (phi1 @ bt_x))
    delta = jnp.sum(qscale)
    return jnp.exp(-lam * delta) * y


def rfd_apply_jit(points, omegas, qscale, x, lam, mask):
    """Tuple-wrapped variant for AOT lowering (return_tuple interchange)."""
    return (rfd_apply(points, omegas, qscale, x, lam, mask),)
