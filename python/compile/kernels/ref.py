"""Pure-jnp oracle for the Pallas kernels — the build-time correctness
reference. Everything here is deliberately naive; pytest asserts the
Pallas kernels match these to float32 tolerance.
"""

import jax.numpy as jnp
import jax.scipy.linalg as jsl


def rf_features_ref(points, omegas, qscale):
    """Reference random-feature maps (see rf_features.py for the math)."""
    phase = points @ omegas.T  # (n, m)
    c = jnp.cos(phase)
    s = jnp.sin(phase)
    b = jnp.stack([c, s], axis=-1).reshape(points.shape[0], -1)
    a = jnp.stack([qscale[None, :] * c, qscale[None, :] * s], axis=-1).reshape(
        points.shape[0], -1
    )
    return a, b


def rfd_apply_ref(points, omegas, qscale, x, lam):
    """Reference RFD integration: exp(Λ(ABᵀ − δI)) x via dense expm.

    O(N³) — only usable for small N in tests.
    """
    a, b = rf_features_ref(points, omegas, qscale)
    w_hat = a @ b.T
    delta = jnp.sum(qscale)  # Σ q_j/m — the exact RF diagonal
    w0 = w_hat - delta * jnp.eye(points.shape[0], dtype=points.dtype)
    k = jsl.expm(lam * w0)
    return k @ x
