"""L1 — Pallas kernel: the RFDiffusion random-feature maps.

Computes the factor matrices A, B of the low-rank adjacency estimate
W_G ≈ A Bᵀ (paper §2.4): for point n_i and frequency ω_j with importance
weight q_j,

    A[i, 2j]   = (q_j / m) · cos(ω_jᵀ n_i)      B[i, 2j]   = cos(ω_jᵀ n_i)
    A[i, 2j+1] = (q_j / m) · sin(ω_jᵀ n_i)      B[i, 2j+1] = sin(ω_jᵀ n_i)

The kernel is tiled over the point dimension with BlockSpec: each grid
step loads a (BLOCK_N, 3) tile of points into VMEM together with the full
(m, 3) frequency matrix, computes the (BLOCK_N, m) phase outer product on
the MXU, and the trig features on the VPU. VMEM per tile at BLOCK_N=256,
m=64: 256·3·4 + 2·256·128·4 + 64·4·4 ≈ 266 KiB — far below the ~16 MiB
budget; the kernel is HBM-bandwidth-bound (DESIGN.md §Hardware
adaptation).

`interpret=True` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU lowering is a compile-only target.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256


def _rf_kernel(points_ref, omegas_ref, qscale_ref, a_ref, b_ref):
    """One tile: points (BLOCK_N, 3) × omegas (m, 3) → features (BLOCK_N, 2m)."""
    pts = points_ref[...]  # (bn, 3)
    om = omegas_ref[...]  # (m, 3)
    qs = qscale_ref[...]  # (m,)
    # Phase outer product — the MXU-shaped contraction.
    phase = jnp.dot(pts, om.T)  # (bn, m)
    c = jnp.cos(phase)
    s = jnp.sin(phase)
    # Interleave cos/sin into the 2m feature axis.
    b = jnp.stack([c, s], axis=-1).reshape(pts.shape[0], -1)  # (bn, 2m)
    qc = qs[None, :] * c
    qsn = qs[None, :] * s
    a = jnp.stack([qc, qsn], axis=-1).reshape(pts.shape[0], -1)
    a_ref[...] = a
    b_ref[...] = b


@functools.partial(jax.jit, static_argnames=())
def rf_features(points, omegas, qscale):
    """Pallas-tiled feature maps.

    Args:
      points: (N, 3) float32, N divisible by BLOCK_N (callers pad).
      omegas: (m, 3) float32 frequencies.
      qscale: (m,) float32 = q_j / m (importance weight over feature count).

    Returns:
      (A, B): each (N, 2m) float32.
    """
    n, _ = points.shape
    m = omegas.shape[0]
    assert n % BLOCK_N == 0, f"N={n} must be a multiple of {BLOCK_N}"
    grid = (n // BLOCK_N,)
    out_shape = [
        jax.ShapeDtypeStruct((n, 2 * m), jnp.float32),
        jax.ShapeDtypeStruct((n, 2 * m), jnp.float32),
    ]
    return pl.pallas_call(
        _rf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, 3), lambda i: (i, 0)),
            pl.BlockSpec((m, 3), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_N, 2 * m), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, 2 * m), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(points, omegas, qscale)
