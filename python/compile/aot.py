"""AOT export: lower the L2 model to HLO *text* artifacts for the Rust
runtime (PJRT via the `xla` crate).

HLO text — NOT `lowered.compile()` / serialized protos — is the
interchange format: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Artifacts are emitted at a ladder of static shape buckets; the Rust
coordinator pads each request to the nearest bucket:

    artifacts/rfd_n{N}_m{m}_d{D}.hlo.txt
    artifacts/manifest.json

Run: `python -m compile.aot --out-dir ../artifacts` (or `make artifacts`).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import rfd_apply_jit

# (N, m, d) buckets. N must be a multiple of the Pallas BLOCK_N (256).
BUCKETS = [
    (256, 16, 4),
    (1024, 16, 4),
    (4096, 16, 4),
    (1024, 32, 4),
    (4096, 32, 4),
    (16384, 16, 4),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(n: int, m: int, d: int) -> str:
    f32 = jnp.float32
    specs = (
        jax.ShapeDtypeStruct((n, 3), f32),      # points
        jax.ShapeDtypeStruct((m, 3), f32),      # omegas
        jax.ShapeDtypeStruct((m,), f32),        # qscale
        jax.ShapeDtypeStruct((n, d), f32),      # x
        jax.ShapeDtypeStruct((), f32),          # lam
        jax.ShapeDtypeStruct((n,), f32),        # mask
    )
    lowered = jax.jit(rfd_apply_jit).lower(*specs)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--buckets",
        default="",
        help="comma list like 256x16x4,1024x16x4 (default: built-in ladder)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    buckets = BUCKETS
    if args.buckets:
        buckets = [tuple(int(t) for t in b.split("x")) for b in args.buckets.split(",")]
    manifest = []
    for n, m, d in buckets:
        name = f"rfd_n{n}_m{m}_d{d}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_bucket(n, m, d)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            {"file": name, "n": n, "m": m, "d": d, "entry": "rfd_apply"}
        )
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest, "block_n": 256}, f, indent=2)
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
