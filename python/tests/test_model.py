"""L2 correctness: the full rfd_apply pipeline vs the dense-expm oracle,
plus AOT lowering smoke checks (HLO text round-trip loadability is
exercised end-to-end from Rust in rust/tests/)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import rfd_apply_ref
from compile.kernels.rf_features import BLOCK_N
from compile.model import rfd_apply
from compile.aot import lower_bucket


def make_problem(n, m, d, seed=0):
    rng = np.random.default_rng(seed)
    points = rng.uniform(-0.5, 0.5, size=(n, 3)).astype(np.float32)
    omegas = (rng.normal(size=(m, 3)) * 3.0).astype(np.float32)
    qscale = (rng.uniform(0.1, 2.0, size=(m,)) / m).astype(np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    return map(jnp.asarray, (points, omegas, qscale, x))


def ones_mask(n):
    return jnp.ones((n,), jnp.float32)


def test_rfd_apply_matches_dense_expm():
    pts, om, qs, x = make_problem(BLOCK_N, 8, 4)
    lam = jnp.float32(-0.2)
    fast = rfd_apply(pts, om, qs, x, lam, ones_mask(x.shape[0]))
    slow = rfd_apply_ref(pts, om, qs, x, lam)
    np.testing.assert_allclose(fast, slow, rtol=2e-3, atol=2e-4)


def test_identity_at_lambda_zero():
    pts, om, qs, x = make_problem(BLOCK_N, 4, 2, seed=1)
    out = rfd_apply(pts, om, qs, x, jnp.float32(0.0), ones_mask(x.shape[0]))
    np.testing.assert_allclose(out, x, rtol=1e-5, atol=1e-5)


def test_scalar_column_consistency():
    # Applying to [x1 | x2] must equal applying per column.
    pts, om, qs, x = make_problem(BLOCK_N, 8, 2, seed=2)
    lam = jnp.float32(-0.3)
    both = rfd_apply(pts, om, qs, x, lam, ones_mask(x.shape[0]))
    col0 = rfd_apply(pts, om, qs, x[:, :1], lam, ones_mask(x.shape[0]))
    np.testing.assert_allclose(both[:, :1], col0, rtol=1e-5, atol=1e-6)


def test_lowering_emits_hlo_text():
    text = lower_bucket(256, 16, 4)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_lowered_shapes_in_hlo():
    text = lower_bucket(256, 16, 4)
    # Entry params must carry the bucket shapes.
    assert "f32[256,3]" in text
    assert "f32[16,3]" in text
    assert "f32[256,4]" in text


def test_mask_padding_exact():
    # Doubling N with zero-mask padding must reproduce the unpadded
    # output exactly on the real rows — the invariant the Rust runtime's
    # bucket padding relies on.
    pts, om, qs, x = make_problem(BLOCK_N, 8, 4, seed=3)
    lam = jnp.float32(-0.25)
    base = rfd_apply(pts, om, qs, x, lam, ones_mask(BLOCK_N))
    pad_pts = jnp.concatenate([pts, jnp.full((BLOCK_N, 3), 7.7, jnp.float32)])
    pad_x = jnp.concatenate([x, jnp.zeros((BLOCK_N, x.shape[1]), jnp.float32)])
    mask = jnp.concatenate([jnp.ones(BLOCK_N), jnp.zeros(BLOCK_N)]).astype(jnp.float32)
    padded = rfd_apply(pad_pts, om, qs, pad_x, lam, mask)
    np.testing.assert_allclose(padded[:BLOCK_N], base, rtol=1e-5, atol=1e-6)
