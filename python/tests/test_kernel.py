"""L1 correctness: the Pallas random-feature kernel vs the pure-jnp
oracle, swept over shapes and magnitudes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import rf_features_ref
from compile.kernels.rf_features import rf_features, BLOCK_N


def make_inputs(n, m, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    points = rng.uniform(-0.5, 0.5, size=(n, 3)).astype(np.float32) * scale
    omegas = rng.normal(size=(m, 3)).astype(np.float32) * 3.0
    qscale = rng.uniform(0.1, 2.0, size=(m,)).astype(np.float32) / m
    return jnp.asarray(points), jnp.asarray(omegas), jnp.asarray(qscale)


def test_matches_ref_basic():
    pts, om, qs = make_inputs(BLOCK_N, 16)
    a, b = rf_features(pts, om, qs)
    a_ref, b_ref = rf_features_ref(pts, om, qs)
    np.testing.assert_allclose(a, a_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b, b_ref, rtol=1e-5, atol=1e-6)


def test_multi_block_grid():
    pts, om, qs = make_inputs(4 * BLOCK_N, 8, seed=1)
    a, b = rf_features(pts, om, qs)
    a_ref, b_ref = rf_features_ref(pts, om, qs)
    np.testing.assert_allclose(a, a_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b, b_ref, rtol=1e-5, atol=1e-6)


def test_rejects_unaligned_n():
    pts, om, qs = make_inputs(BLOCK_N, 4)
    with pytest.raises(AssertionError):
        rf_features(pts[: BLOCK_N - 1], om, qs)


def test_feature_gram_estimates_indicator_scale():
    # A Bᵀ rows should estimate Σ q_j cos(ω(n_i−n_k))/m: check the exact
    # algebraic identity (A Bᵀ)_ik == Σ_j qscale_j cos(ω_jᵀ(n_i − n_k)).
    pts, om, qs = make_inputs(BLOCK_N, 8, seed=2)
    a, b = rf_features(pts, om, qs)
    w = np.asarray(a @ b.T)
    i, k = 3, 77
    z = np.asarray(pts[i] - pts[k])
    want = float(np.sum(np.asarray(qs) * np.cos(np.asarray(om) @ z)))
    np.testing.assert_allclose(w[i, k], want, rtol=1e-4, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=3),
    m=st.sampled_from([2, 4, 16, 32]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
)
def test_hypothesis_shape_sweep(blocks, m, seed, scale):
    pts, om, qs = make_inputs(blocks * BLOCK_N, m, seed=seed, scale=scale)
    a, b = rf_features(pts, om, qs)
    a_ref, b_ref = rf_features_ref(pts, om, qs)
    np.testing.assert_allclose(a, a_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(b, b_ref, rtol=1e-4, atol=1e-5)


def test_float32_dtype_preserved():
    pts, om, qs = make_inputs(BLOCK_N, 4)
    a, b = rf_features(pts, om, qs)
    assert a.dtype == jnp.float32 and b.dtype == jnp.float32
