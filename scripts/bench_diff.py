#!/usr/bin/env python3
"""Diff two BENCH_*.json files (criterion-lite output) by benchmark median.

Usage: bench_diff.py [--fail-above PCT] PREVIOUS.json CURRENT.json

Prints a per-benchmark table of previous/current medians and the ratio,
flagging cases that moved more than the noise threshold.

By default the diff is report-only and always exits 0 (CI smoke budgets
are too noisy to gate merges on). With `--fail-above PCT` the script
exits 1 when any benchmark's current median exceeds its previous median
by more than PCT percent (e.g. `--fail-above 50` fails on a >1.5x
slowdown) — the opt-in gate for runs with real budgets (see
docs/ARCHITECTURE.md, "Performance tracking").
"""

import json
import sys

REGRESSION = 1.25  # current/previous median above this → flagged slower
IMPROVEMENT = 0.80  # below this → flagged faster


def load(path):
    with open(path) as fh:
        doc = json.load(fh)
    return {b["name"]: b["median_ns"] for b in doc.get("benchmarks", [])}


def fmt_ns(ns):
    if ns >= 1e9:
        return f"{ns / 1e9:.2f} s"
    if ns >= 1e6:
        return f"{ns / 1e6:.2f} ms"
    if ns >= 1e3:
        return f"{ns / 1e3:.2f} µs"
    return f"{ns:.0f} ns"


def parse_args(argv):
    fail_above = None
    paths = []
    i = 0
    while i < len(argv):
        arg = argv[i]
        if arg == "--fail-above":
            if i + 1 >= len(argv):
                print("--fail-above needs a percentage", file=sys.stderr)
                return None
            try:
                fail_above = float(argv[i + 1])
            except ValueError:
                print(f"--fail-above: not a number: {argv[i + 1]!r}", file=sys.stderr)
                return None
            i += 2
        elif arg.startswith("--fail-above="):
            try:
                fail_above = float(arg.split("=", 1)[1])
            except ValueError:
                print(f"--fail-above: not a number: {arg!r}", file=sys.stderr)
                return None
            i += 1
        else:
            paths.append(arg)
            i += 1
    if len(paths) != 2:
        return None
    return fail_above, paths[0], paths[1]


def main():
    parsed = parse_args(sys.argv[1:])
    if parsed is None:
        print(__doc__, file=sys.stderr)
        return 2
    fail_above, prev_path, cur_path = parsed
    prev, cur = load(prev_path), load(cur_path)
    names = sorted(set(prev) | set(cur))
    width = max((len(n) for n in names), default=4)
    print(f"{'benchmark':<{width}}  {'previous':>12}  {'current':>12}  {'ratio':>7}  flag")
    slower, faster, failures = [], [], []
    fail_ratio = None if fail_above is None else 1.0 + fail_above / 100.0
    for name in names:
        p, c = prev.get(name), cur.get(name)
        if p is None:
            print(f"{name:<{width}}  {'—':>12}  {fmt_ns(c):>12}  {'new':>7}")
            continue
        if c is None:
            print(f"{name:<{width}}  {fmt_ns(p):>12}  {'—':>12}  {'gone':>7}")
            continue
        ratio = c / p if p > 0 else float("inf")
        flag = ""
        if ratio > REGRESSION:
            flag = "SLOWER"
            slower.append(name)
        elif ratio < IMPROVEMENT:
            flag = "faster"
            faster.append(name)
        if fail_ratio is not None and ratio > fail_ratio:
            flag = (flag + " FAIL").strip()
            failures.append(name)
        print(f"{name:<{width}}  {fmt_ns(p):>12}  {fmt_ns(c):>12}  {ratio:>6.2f}x  {flag}")
    print()
    print(
        f"{len(names)} benchmarks: {len(slower)} slower (> {REGRESSION}x), "
        f"{len(faster)} faster (< {IMPROVEMENT}x)"
    )
    if slower:
        print("slower:", ", ".join(slower))
    if failures:
        print(
            f"FAIL: {len(failures)} benchmark(s) regressed past the "
            f"--fail-above {fail_above}% gate:",
            ", ".join(failures),
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
