#!/usr/bin/env python3
"""Diff two BENCH_*.json files (criterion-lite output) by benchmark median.

Usage: bench_diff.py PREVIOUS.json CURRENT.json

Prints a per-benchmark table of previous/current medians and the ratio,
flagging cases that moved more than the noise threshold. Report-only:
always exits 0 (CI smoke budgets are too noisy to gate merges on).
"""

import json
import sys

REGRESSION = 1.25  # current/previous median above this → flagged slower
IMPROVEMENT = 0.80  # below this → flagged faster


def load(path):
    with open(path) as fh:
        doc = json.load(fh)
    return {b["name"]: b["median_ns"] for b in doc.get("benchmarks", [])}


def fmt_ns(ns):
    if ns >= 1e9:
        return f"{ns / 1e9:.2f} s"
    if ns >= 1e6:
        return f"{ns / 1e6:.2f} ms"
    if ns >= 1e3:
        return f"{ns / 1e3:.2f} µs"
    return f"{ns:.0f} ns"


def main():
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    prev, cur = load(sys.argv[1]), load(sys.argv[2])
    names = sorted(set(prev) | set(cur))
    width = max((len(n) for n in names), default=4)
    print(f"{'benchmark':<{width}}  {'previous':>12}  {'current':>12}  {'ratio':>7}  flag")
    slower, faster = [], []
    for name in names:
        p, c = prev.get(name), cur.get(name)
        if p is None:
            print(f"{name:<{width}}  {'—':>12}  {fmt_ns(c):>12}  {'new':>7}")
            continue
        if c is None:
            print(f"{name:<{width}}  {fmt_ns(p):>12}  {'—':>12}  {'gone':>7}")
            continue
        ratio = c / p if p > 0 else float("inf")
        flag = ""
        if ratio > REGRESSION:
            flag = "SLOWER"
            slower.append(name)
        elif ratio < IMPROVEMENT:
            flag = "faster"
            faster.append(name)
        print(f"{name:<{width}}  {fmt_ns(p):>12}  {fmt_ns(c):>12}  {ratio:>6.2f}x  {flag}")
    print()
    print(
        f"{len(names)} benchmarks: {len(slower)} slower (> {REGRESSION}x), "
        f"{len(faster)} faster (< {IMPROVEMENT}x)"
    )
    if slower:
        print("slower:", ", ".join(slower))
    return 0


if __name__ == "__main__":
    sys.exit(main())
